//! Delta-aware dynamic CSR: O(batch) maintenance of G *and* Gᵀ.
//!
//! The coordinator used to pay O(N + E) per update — a full counting-sort
//! rebuild of G, a full rebuild of Gᵀ, and a cloned `old_csr` — so
//! small-batch updates were dominated by graph maintenance, not by the
//! rank computation the paper accelerates. [`DynCsr`] keeps both
//! directions in the slack CSR layout (Hornet-style blocked adjacency:
//! each row owns a capacity-padded arena segment) and applies a batch of
//! `I` insertions + `D` deletions in amortized `O((I + D) · log deg)`:
//!
//! * **insert** — binary search in the sorted row, shift the tail right
//!   one slot; a full row relocates to the arena tail with doubled
//!   capacity (amortized O(1) relocations per slot, as in a growable
//!   vector);
//! * **delete** — binary search, shift the tail left (capacity is kept, so
//!   a later re-insert is free);
//! * **compaction** — when the arena grows past
//!   [`slack_limit`] (relocations leave dead regions behind; deletions
//!   strand capacity), the side is repacked row-by-row with fresh headroom.
//!   The trigger depends only on the logical graph and the edit history,
//!   never on timing, so layouts are reproducible.
//!
//! Alongside the adjacency itself, the structure incrementally maintains
//! what the engines would otherwise recompute per run: the out-degree f64
//! cache (`CsrGraph::degrees_f64`, the asynchronous engines' fused
//! gather-divide divisor) on both sides, and the in-degree hub list
//! (`partition_by_degree(..).high()` at [`HUB_DEGREE_THRESHOLD`]) on Gᵀ,
//! patched on threshold crossings.
//!
//! # Determinism contract (neighbor order)
//!
//! Ranks must be **bitwise identical** between the incremental and rebuild
//! paths. The engines' floating-point results depend on neighbor *order*
//! (gathers stripe a row's in-neighbors across SIMD lanes in row order) —
//! so both paths pin the same order contract: **every row is sorted
//! ascending**. `GraphBuilder` keeps its rows sorted (binary-search
//! insert), so a counting-sort rebuild emits sorted rows; `DynCsr` inserts
//! in sorted position directly. Row *placement* in the arena (slack,
//! relocations, compaction) is invisible to the kernels: hub chunk
//! boundaries are relative to the row start, per-vertex gathers see only
//! the row slice, and the contribution kernel reads `(starts, ends)` pairs
//! whose differences are the same degree integers in both layouts.
//! `tests/incremental_csr.rs` holds the equivalence matrix.
//!
//! # Escape hatch
//!
//! [`CsrMode`] on `PagerankConfig` (mirroring `pool_persistent` /
//! `PAGERANK_SIMD`): `Auto` (default) resolves to the incremental path
//! unless the `PAGERANK_CSR=rebuild` environment pin selects the legacy
//! full-rebuild path; `Rebuild` / `Incremental` override the environment.
//! `ci.sh` runs the digest gate under both settings and diffs the bits.

use super::{CsrGraph, GraphBuilder, VertexId};
use crate::batch::BatchUpdate;

/// Degree above which a vertex takes the hub (edge-chunked) path in the
/// native pull kernels; the maintained hub cache uses the same threshold.
pub(crate) const HUB_DEGREE_THRESHOLD: u32 = 1024;

/// How the coordinator maintains its CSR snapshots across batch updates.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CsrMode {
    /// Honor the `PAGERANK_CSR` environment pin if set (`rebuild` forces
    /// the legacy full-rebuild path, anything else the incremental
    /// structure); otherwise maintain incrementally. The default.
    #[default]
    Auto,
    /// Force the legacy path: full counting-sort rebuild of G plus full
    /// transpose per update — the escape hatch, and the reference side of
    /// the incremental-vs-rebuild differential tests.
    Rebuild,
    /// Force the incremental [`DynCsr`] structure.
    Incremental,
}

impl CsrMode {
    /// Serialization name (checkpoints, reports).
    pub fn as_str(&self) -> &'static str {
        match self {
            CsrMode::Auto => "auto",
            CsrMode::Rebuild => "rebuild",
            CsrMode::Incremental => "incremental",
        }
    }

    /// Parse a serialization name.
    pub fn parse(s: &str) -> Option<CsrMode> {
        match s {
            "auto" => Some(CsrMode::Auto),
            "rebuild" => Some(CsrMode::Rebuild),
            "incremental" => Some(CsrMode::Incremental),
            _ => None,
        }
    }

    /// Resolve to "maintain incrementally?": explicit settings win, `Auto`
    /// consults the `PAGERANK_CSR` environment pin (used by ci.sh to run
    /// the whole suite on each side of the differential).
    pub fn resolve_incremental(self) -> bool {
        match self {
            CsrMode::Rebuild => false,
            CsrMode::Incremental => true,
            CsrMode::Auto => !matches!(
                std::env::var("PAGERANK_CSR"),
                Ok(s) if s.trim() == "rebuild"
            ),
        }
    }
}

/// Initial / post-compaction headroom for a row of `len` edges: 12.5%
/// plus a couple of slots, so small rows absorb a few insertions before
/// relocating and the arena stays within ~1.2× the packed size.
fn target_cap(len: usize) -> u64 {
    (len + len / 8 + 2) as u64
}

/// Compaction trigger: repack a side when its arena exceeds this. The
/// fresh layout uses ≈ 1.125·m + 2n slots, so the bound allows roughly
/// another 0.9·m + 2n slots of relocation/deletion waste between repacks.
fn slack_limit(n: usize, m: usize) -> usize {
    2 * m + 4 * n + 64
}

/// One slack-CSR side plus its per-row capacities.
#[derive(Debug, Clone)]
struct Side {
    csr: CsrGraph,
    caps: Vec<u64>,
}

impl Side {
    /// Lay out sorted rows with [`target_cap`] headroom each.
    fn from_rows(rows: &[&[VertexId]]) -> Side {
        let n = rows.len();
        let mut offsets = vec![0u64; n + 1];
        let mut ends = vec![0u64; n];
        let mut caps = vec![0u64; n];
        let mut arena = 0u64;
        let mut m = 0usize;
        for (v, row) in rows.iter().enumerate() {
            offsets[v] = arena;
            ends[v] = arena + row.len() as u64;
            caps[v] = target_cap(row.len());
            arena += caps[v];
            m += row.len();
        }
        offsets[n] = arena;
        let mut targets = vec![0 as VertexId; arena as usize];
        for (v, row) in rows.iter().enumerate() {
            let s = offsets[v] as usize;
            targets[s..s + row.len()].copy_from_slice(row);
        }
        Side { csr: CsrGraph::slack(offsets, ends, targets, m), caps }
    }

    #[inline]
    fn row_len(&self, v: usize) -> usize {
        self.csr.row_end(v) - self.csr.row_start(v)
    }

    /// Insert `x` into sorted row `v`; returns false if already present.
    fn insert(&mut self, v: usize, x: VertexId) -> bool {
        let s = self.csr.row_start(v);
        let e = self.csr.row_end(v);
        let pos = match self.csr.targets[s..e].binary_search(&x) {
            Ok(_) => return false,
            Err(p) => p,
        };
        let len = e - s;
        let (s, e) = if len as u64 == self.caps[v] {
            // Full row: relocate to the arena tail with doubled capacity
            // (the old segment becomes dead space until compaction).
            let new_cap = (self.caps[v] * 2).max(target_cap(len + 1)).max(4);
            let ns = self.csr.targets.len();
            self.csr.targets.extend_from_within(s..e);
            self.csr.targets.resize(ns + new_cap as usize, 0);
            self.csr.offsets[v] = ns as u64;
            self.caps[v] = new_cap;
            (ns, ns + len)
        } else {
            (s, e)
        };
        self.csr.targets.copy_within(s + pos..e, s + pos + 1);
        self.csr.targets[s + pos] = x;
        self.csr.ends.as_mut().expect("slack layout")[v] = (e + 1) as u64;
        self.csr.m += 1;
        true
    }

    /// Remove `x` from sorted row `v`; returns false if absent. Capacity
    /// is kept, so delete-then-reinsert churn never relocates.
    fn remove(&mut self, v: usize, x: VertexId) -> bool {
        let s = self.csr.row_start(v);
        let e = self.csr.row_end(v);
        let pos = match self.csr.targets[s..e].binary_search(&x) {
            Ok(p) => p,
            Err(_) => return false,
        };
        self.csr.targets.copy_within(s + pos + 1..e, s + pos);
        self.csr.ends.as_mut().expect("slack layout")[v] = (e - 1) as u64;
        self.csr.m -= 1;
        true
    }

    /// Repack the arena row-by-row with fresh [`target_cap`] headroom.
    /// Rows, caches and the logical graph are untouched — only placement
    /// changes, which the kernels never observe.
    fn compact(&mut self) {
        let n = self.csr.num_vertices();
        let mut offsets = vec![0u64; n + 1];
        let mut ends = vec![0u64; n];
        let mut arena = 0u64;
        for v in 0..n {
            offsets[v] = arena;
            let len = self.row_len(v);
            ends[v] = arena + len as u64;
            self.caps[v] = target_cap(len);
            arena += self.caps[v];
        }
        offsets[n] = arena;
        let mut targets = vec![0 as VertexId; arena as usize];
        for v in 0..n {
            let s = self.csr.row_start(v);
            let e = self.csr.row_end(v);
            targets[offsets[v] as usize..ends[v] as usize]
                .copy_from_slice(&self.csr.targets[s..e]);
        }
        self.csr.offsets = offsets;
        self.csr.ends = Some(ends);
        self.csr.targets = targets;
    }
}

/// Incrementally-maintained G and Gᵀ (see the module docs). Created from
/// the coordinator's `GraphBuilder` and kept in lockstep with it by
/// [`DynCsr::apply_batch`] — both sides always expose exactly the logical
/// graph a `to_csr()` + `transpose()` rebuild would produce.
#[derive(Debug, Clone)]
pub struct DynCsr {
    g: Side,
    gt: Side,
    compactions: u64,
}

impl DynCsr {
    /// Build both sides from the builder's (sorted) rows, seeding the
    /// degree and hub caches.
    pub fn from_builder(b: &GraphBuilder) -> DynCsr {
        let n = b.num_vertices();
        let rows: Vec<&[VertexId]> =
            (0..n).map(|u| b.out_neighbors(u as VertexId)).collect();
        let g = Side::from_rows(&rows);
        // Transpose rows: ascending-source iteration keeps them sorted.
        let mut tadj: Vec<Vec<VertexId>> = vec![Vec::new(); n];
        for (u, v) in b.edges() {
            tadj[v as usize].push(u);
        }
        let trows: Vec<&[VertexId]> = tadj.iter().map(|r| r.as_slice()).collect();
        let gt = Side::from_rows(&trows);
        let mut dc = DynCsr { g, gt, compactions: 0 };
        dc.g.csr.deg_f64_cache =
            Some((0..n).map(|v| dc.g.row_len(v) as f64).collect());
        dc.gt.csr.deg_f64_cache =
            Some((0..n).map(|v| dc.gt.row_len(v) as f64).collect());
        let hubs: Vec<VertexId> = (0..n as VertexId)
            .filter(|&v| dc.gt.row_len(v as usize) as u32 > HUB_DEGREE_THRESHOLD)
            .collect();
        dc.gt.csr.hub_cache = Some((HUB_DEGREE_THRESHOLD, hubs));
        dc
    }

    /// The maintained `(G, Gᵀ)` views, ready for the engines.
    pub fn graphs(&self) -> (&CsrGraph, &CsrGraph) {
        (&self.g.csr, &self.gt.csr)
    }

    /// Logical edge count (either side; they are always equal).
    pub fn num_edges(&self) -> usize {
        self.g.csr.num_edges()
    }

    /// Total side-compactions so far (observability / tests).
    pub fn compactions(&self) -> u64 {
        self.compactions
    }

    /// Apply a *validated* batch — the same clean subset `batch::apply`
    /// feeds the builder, in the same order (deletions, then insertions;
    /// the self-loop re-add is a no-op because validation rejects
    /// self-loop edits and every vertex keeps its protected loop from
    /// construction). Returns the number of edges changed, equal to the
    /// builder's count by the lockstep invariant.
    pub fn apply_batch(&mut self, batch: &BatchUpdate) -> usize {
        let mut changed = 0usize;
        for &(u, v) in &batch.deletions {
            if u == v {
                continue; // protected self-loops, mirroring GraphBuilder
            }
            if self.g.remove(u as usize, v) {
                let removed = self.gt.remove(v as usize, u);
                debug_assert!(removed, "G/Gᵀ desynchronized on ({u}, {v})");
                self.after_edit(u, v);
                changed += 1;
            }
        }
        for &(u, v) in &batch.insertions {
            if u == v {
                continue; // validation rejects these; stay in lockstep
            }
            if self.g.insert(u as usize, v) {
                let inserted = self.gt.insert(v as usize, u);
                debug_assert!(inserted, "G/Gᵀ desynchronized on ({u}, {v})");
                self.after_edit(u, v);
                changed += 1;
            }
        }
        self.maybe_compact();
        changed
    }

    /// Patch the degree caches and the Gᵀ hub list after one applied edit
    /// on edge (u, v). Each edit moves the touched degrees by exactly one,
    /// so threshold crossings are local insert/remove operations on the
    /// ascending hub list.
    fn after_edit(&mut self, u: VertexId, v: VertexId) {
        let gdeg = self.g.row_len(u as usize) as f64;
        if let Some(c) = self.g.csr.deg_f64_cache.as_mut() {
            c[u as usize] = gdeg;
        }
        let tdeg = self.gt.row_len(v as usize);
        if let Some(c) = self.gt.csr.deg_f64_cache.as_mut() {
            c[v as usize] = tdeg as f64;
        }
        if let Some((t, hubs)) = self.gt.csr.hub_cache.as_mut() {
            let t = *t as usize;
            if tdeg == t + 1 {
                // crossed up: in-degree was t (low), now t + 1 (hub)
                if let Err(pos) = hubs.binary_search(&v) {
                    hubs.insert(pos, v);
                }
            } else if tdeg == t {
                // crossed down: was t + 1 (hub), now t (low)
                if let Ok(pos) = hubs.binary_search(&v) {
                    hubs.remove(pos);
                }
            }
        }
    }

    /// Repack any side whose arena outgrew [`slack_limit`]. Deterministic:
    /// the trigger is a function of the edit history only.
    fn maybe_compact(&mut self) {
        let n = self.g.csr.num_vertices();
        let m = self.g.csr.num_edges();
        if self.g.csr.targets.len() > slack_limit(n, m) {
            self.g.compact();
            self.compactions += 1;
        }
        if self.gt.csr.targets.len() > slack_limit(n, m) {
            self.gt.compact();
            self.compactions += 1;
        }
    }

    /// Packed logical copies of both sides (tests, checkpoint tooling):
    /// the exact graphs a full rebuild would produce.
    pub fn to_packed(&self) -> (CsrGraph, CsrGraph) {
        let pack = |side: &Side| {
            let n = side.csr.num_vertices();
            let rows: Vec<&[VertexId]> =
                (0..n).map(|v| side.csr.neighbors(v as VertexId)).collect();
            let mut offsets = Vec::with_capacity(n + 1);
            let mut total = 0u64;
            offsets.push(0);
            let mut targets = Vec::with_capacity(side.csr.num_edges());
            for row in &rows {
                total += row.len() as u64;
                offsets.push(total);
                targets.extend_from_slice(row);
            }
            CsrGraph::packed(offsets, targets)
        };
        (pack(&self.g), pack(&self.gt))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batch::{self, random_batch};
    use crate::generators::er;

    fn assert_lockstep(dc: &DynCsr, b: &GraphBuilder) {
        let (g, gt) = dc.graphs();
        let want_g = b.to_csr();
        let want_gt = want_g.transpose();
        assert_eq!(g, &want_g, "forward side diverged");
        assert_eq!(gt, &want_gt, "transpose side diverged");
        assert_eq!(dc.num_edges(), b.num_edges());
        // caches match a cold recompute bit-for-bit
        assert_eq!(g.degrees_f64(), want_g.degrees_f64());
        assert_eq!(gt.degrees_f64(), want_gt.degrees_f64());
        let hubs = gt.cached_hubs(HUB_DEGREE_THRESHOLD).expect("hub cache");
        let want_hubs = crate::graph::partition_by_degree(
            &want_gt.degrees(),
            HUB_DEGREE_THRESHOLD,
        );
        assert_eq!(hubs, want_hubs.high(), "hub cache diverged");
    }

    #[test]
    fn tracks_builder_through_random_batches() {
        let mut b = er::generate(400, 5.0, 17);
        b.ensure_self_loops();
        let mut dc = DynCsr::from_builder(&b);
        assert_lockstep(&dc, &b);
        for seed in 0..12 {
            let upd = random_batch(&b, 40, 0.7, seed);
            let validated = batch::validate(&b, &upd);
            let applied = batch::apply(&mut b, &validated.clean);
            let got = dc.apply_batch(&validated.clean);
            assert_eq!(got, applied, "changed-edge count, seed {seed}");
            assert_lockstep(&dc, &b);
        }
    }

    #[test]
    fn row_overflow_relocates() {
        // start from bare self-loops (row capacity 3), then grow vertex 0's
        // out-row through several doublings — every insert after the third
        // lands in a relocated segment
        let mut b = GraphBuilder::new(64);
        b.ensure_self_loops();
        let mut dc = DynCsr::from_builder(&b);
        for v in 1..64u32 {
            let upd = BatchUpdate { deletions: vec![], insertions: vec![(0, v)] };
            batch::apply(&mut b, &upd);
            dc.apply_batch(&upd);
        }
        assert_lockstep(&dc, &b);
        assert_eq!(dc.graphs().0.degree(0), 64);
        assert_eq!(dc.compactions(), 0, "growth alone stays under the limit");
    }

    #[test]
    fn graph_emptying_batch_triggers_compaction() {
        // a dense seed graph whose arena (≈ 1.125·m + 2n) far exceeds the
        // post-deletion slack limit (2·m' + 4n + 64 with m' = n self-loops)
        let mut b = er::generate(500, 20.0, 3);
        b.ensure_self_loops();
        let mut dc = DynCsr::from_builder(&b);
        assert!(b.num_edges() > 8_000, "seed graph unexpectedly sparse");
        let wipe = BatchUpdate { deletions: b.real_edges(), insertions: vec![] };
        let validated = batch::validate(&b, &wipe);
        let applied = batch::apply(&mut b, &validated.clean);
        let got = dc.apply_batch(&validated.clean);
        assert_eq!(got, applied);
        assert_eq!(b.num_edges(), 500, "only protected self-loops remain");
        assert!(dc.compactions() > 0, "emptying batch must trip compaction");
        assert_lockstep(&dc, &b);
        // the structure keeps working after the repack
        let refill = random_batch(&b, 200, 1.0, 8);
        let validated = batch::validate(&b, &refill);
        batch::apply(&mut b, &validated.clean);
        dc.apply_batch(&validated.clean);
        assert_lockstep(&dc, &b);
    }

    #[test]
    fn hub_threshold_crossings_patch_the_cache() {
        let n = (HUB_DEGREE_THRESHOLD + 10) as usize;
        let mut b = GraphBuilder::new(n);
        b.ensure_self_loops();
        let mut dc = DynCsr::from_builder(&b);
        // push vertex 3's in-degree across the hub threshold and back
        let ins: Vec<(VertexId, VertexId)> = (0..n as VertexId)
            .filter(|&u| u != 3)
            .map(|u| (u, 3))
            .collect();
        let up = BatchUpdate { deletions: vec![], insertions: ins.clone() };
        batch::apply(&mut b, &up);
        dc.apply_batch(&up);
        assert_lockstep(&dc, &b);
        assert_eq!(
            dc.graphs().1.cached_hubs(HUB_DEGREE_THRESHOLD),
            Some(&[3u32][..])
        );
        let down = BatchUpdate { deletions: ins, insertions: vec![] };
        batch::apply(&mut b, &down);
        dc.apply_batch(&down);
        assert_lockstep(&dc, &b);
        let hubs = dc.graphs().1.cached_hubs(HUB_DEGREE_THRESHOLD).unwrap();
        assert!(hubs.is_empty(), "vertex 3 must leave the hub cache");
    }

    #[test]
    fn csr_mode_parse_roundtrip_and_resolution() {
        for m in [CsrMode::Auto, CsrMode::Rebuild, CsrMode::Incremental] {
            assert_eq!(CsrMode::parse(m.as_str()), Some(m));
        }
        assert_eq!(CsrMode::parse("hornet"), None);
        assert_eq!(CsrMode::default(), CsrMode::Auto);
        // explicit modes ignore the environment
        assert!(!CsrMode::Rebuild.resolve_incremental());
        assert!(CsrMode::Incremental.resolve_incremental());
    }
}
