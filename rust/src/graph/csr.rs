//! Compressed Sparse Row graph storage.
//!
//! The paper computes ranks by pulling over the *transpose* of the current
//! graph (in-neighbors) and expands frontiers by pushing over the graph
//! itself (out-neighbors); [`CsrGraph`] stores one direction and
//! [`CsrGraph::transpose`] produces the other.
//!
//! The edge-list and transpose builders run their counting and placement
//! passes on the persistent work-stealing pool (`util::par`): each lane
//! histograms a contiguous edge range, a single fused pass turns the
//! per-lane histograms into row offsets and per-lane write cursors, and
//! placement scatters through [`par::DisjointWriter`] (every edge has a
//! unique precomputed slot). The lane→range mapping is fixed by the input
//! size, so whichever worker steals a lane's task produces the same
//! cursors: each row's neighbor order is the original edge order and the
//! output is *identical* (not just equivalent) to the sequential build at
//! every thread count.

use super::VertexId;
use crate::util::par;

/// Below this many edges the parallel build's histogram setup dominates;
/// run the sequential counting sort.
const PAR_BUILD_CUTOFF: usize = 1 << 15;

/// Immutable CSR adjacency: `targets[offsets[v]..offsets[v+1]]` are the
/// neighbors of `v` (out-neighbors by convention; a transposed instance
/// holds in-neighbors).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CsrGraph {
    offsets: Vec<u64>,
    targets: Vec<VertexId>,
}

/// Fuse per-thread histograms (thread-major, `n` entries each) into row
/// offsets and in-place write cursors: after this, `hists[t*n + v]` is the
/// first target slot for thread `t`'s edges with row `v`, and
/// `offsets[v]` is the start of row `v`. Returns the edge total.
fn cursors_from_histograms(n: usize, hists: &mut [u64], offsets: &mut [u64]) -> u64 {
    let lanes = hists.len() / n;
    let mut acc = 0u64;
    for v in 0..n {
        offsets[v] = acc;
        for t in 0..lanes {
            let h = hists[t * n + v];
            hists[t * n + v] = acc;
            acc += h;
        }
    }
    offsets[n] = acc;
    acc
}

impl CsrGraph {
    /// Build from per-vertex adjacency lists.
    pub fn from_adjacency(adj: &[Vec<VertexId>]) -> Self {
        let n = adj.len();
        let mut offsets = Vec::with_capacity(n + 1);
        let mut total = 0u64;
        offsets.push(0);
        for nbrs in adj {
            total += nbrs.len() as u64;
            offsets.push(total);
        }
        let mut targets = Vec::with_capacity(total as usize);
        for nbrs in adj {
            targets.extend_from_slice(nbrs);
        }
        Self { offsets, targets }
    }

    /// Build from an edge list (`n` fixes the vertex count; isolated vertices
    /// get empty rows). Counting pass + placement pass, no sorting; runs on
    /// the pool for large inputs (`threads = 0` means all cores) with output
    /// identical to the sequential build.
    pub fn from_edges_threads(
        n: usize,
        edges: &[(VertexId, VertexId)],
        threads: usize,
    ) -> Self {
        let threads = par::resolve(threads);
        if threads == 1 || edges.len() < PAR_BUILD_CUTOFF {
            return Self::from_edges_seq(n, edges);
        }
        let chunk = edges.len().div_ceil(threads);
        let lanes = edges.len().div_ceil(chunk);

        // parallel counting: one histogram per contiguous edge range, one
        // pool task per lane (block = n aligns par_for's chunks with the
        // per-lane histograms)
        let mut hists = vec![0u64; lanes * n];
        par::par_for(threads, n, &mut hists, |start, hist| {
            let lo = (start / n) * chunk;
            let hi = (lo + chunk).min(edges.len());
            for &(u, _) in &edges[lo..hi] {
                hist[u as usize] += 1;
            }
        });

        let mut offsets = vec![0u64; n + 1];
        cursors_from_histograms(n, &mut hists, &mut offsets);

        // parallel placement: each lane replays its range against its own
        // cursors; slots are disjoint by construction
        let mut targets = vec![0 as VertexId; edges.len()];
        let writer = par::DisjointWriter::new(&mut targets);
        let writer = &writer;
        par::par_for(threads, n, &mut hists, |start, hist| {
            let lo = (start / n) * chunk;
            let hi = (lo + chunk).min(edges.len());
            for &(u, v) in &edges[lo..hi] {
                let c = &mut hist[u as usize];
                unsafe { writer.write(*c as usize, v) };
                *c += 1;
            }
        });
        Self { offsets, targets }
    }

    /// [`CsrGraph::from_edges_threads`] with the full pool.
    pub fn from_edges(n: usize, edges: &[(VertexId, VertexId)]) -> Self {
        Self::from_edges_threads(n, edges, 0)
    }

    fn from_edges_seq(n: usize, edges: &[(VertexId, VertexId)]) -> Self {
        let mut counts = vec![0u64; n + 1];
        for &(u, _) in edges {
            counts[u as usize + 1] += 1;
        }
        for i in 0..n {
            counts[i + 1] += counts[i];
        }
        let offsets = counts.clone();
        let mut cursor = counts;
        let mut targets = vec![0 as VertexId; edges.len()];
        for &(u, v) in edges {
            let c = &mut cursor[u as usize];
            targets[*c as usize] = v;
            *c += 1;
        }
        Self { offsets, targets }
    }

    /// Number of vertices.
    #[inline]
    pub fn num_vertices(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Number of (directed) edges, self-loops included.
    #[inline]
    pub fn num_edges(&self) -> usize {
        self.targets.len()
    }

    /// Neighbors of `v`.
    #[inline]
    pub fn neighbors(&self, v: VertexId) -> &[VertexId] {
        let s = self.offsets[v as usize] as usize;
        let e = self.offsets[v as usize + 1] as usize;
        &self.targets[s..e]
    }

    /// Degree of `v` in this direction.
    #[inline]
    pub fn degree(&self, v: VertexId) -> u32 {
        (self.offsets[v as usize + 1] - self.offsets[v as usize]) as u32
    }

    /// All degrees.
    pub fn degrees(&self) -> Vec<u32> {
        (0..self.num_vertices() as VertexId)
            .map(|v| self.degree(v))
            .collect()
    }

    /// All degrees as f64 (exact — degrees fit far below 2^52), for the
    /// asynchronous engines' fused gather-divide pull (`util::simd`).
    pub fn degrees_f64(&self) -> Vec<f64> {
        self.offsets
            .windows(2)
            .map(|w| (w[1] - w[0]) as f64)
            .collect()
    }

    /// Transposed graph (in-neighbors become out-neighbors), built with the
    /// same parallel counting-sort as [`CsrGraph::from_edges_threads`];
    /// identical output at every thread count.
    pub fn transpose_threads(&self, threads: usize) -> CsrGraph {
        let threads = par::resolve(threads);
        let m = self.targets.len();
        if threads == 1 || m < PAR_BUILD_CUTOFF {
            return self.transpose_seq();
        }
        let n = self.num_vertices();
        let chunk = m.div_ceil(threads);
        let lanes = m.div_ceil(chunk);

        // parallel counting over contiguous target ranges
        let targets = &self.targets;
        let mut hists = vec![0u64; lanes * n];
        par::par_for(threads, n, &mut hists, |start, hist| {
            let lo = (start / n) * chunk;
            let hi = (lo + chunk).min(m);
            for &v in &targets[lo..hi] {
                hist[v as usize] += 1;
            }
        });

        let mut toffsets = vec![0u64; n + 1];
        cursors_from_histograms(n, &mut hists, &mut toffsets);

        // parallel placement: each lane walks its edge range, recovering
        // the source row from the forward offsets
        let offsets = &self.offsets;
        let mut ttargets = vec![0 as VertexId; m];
        let writer = par::DisjointWriter::new(&mut ttargets);
        let writer = &writer;
        par::par_for(threads, n, &mut hists, |start, hist| {
            let lo = (start / n) * chunk;
            let hi = (lo + chunk).min(m);
            // last row whose edge range starts at or before lo
            let mut row = offsets.partition_point(|&o| (o as usize) <= lo) - 1;
            let mut idx = lo;
            while idx < hi {
                let row_end = (offsets[row + 1] as usize).min(hi);
                for &v in &targets[idx..row_end] {
                    let c = &mut hist[v as usize];
                    unsafe { writer.write(*c as usize, row as VertexId) };
                    *c += 1;
                }
                idx = row_end;
                row += 1;
            }
        });
        CsrGraph { offsets: toffsets, targets: ttargets }
    }

    /// [`CsrGraph::transpose_threads`] with the full pool.
    pub fn transpose(&self) -> CsrGraph {
        self.transpose_threads(0)
    }

    fn transpose_seq(&self) -> CsrGraph {
        let n = self.num_vertices();
        let mut counts = vec![0u64; n + 1];
        for &t in &self.targets {
            counts[t as usize + 1] += 1;
        }
        for i in 0..n {
            counts[i + 1] += counts[i];
        }
        let offsets = counts.clone();
        let mut cursor = counts;
        let mut targets = vec![0 as VertexId; self.targets.len()];
        for u in 0..n {
            for &v in self.neighbors(u as VertexId) {
                let c = &mut cursor[v as usize];
                targets[*c as usize] = u as VertexId;
                *c += 1;
            }
        }
        CsrGraph { offsets, targets }
    }

    /// Iterate all edges `(u, v)`.
    pub fn edges(&self) -> impl Iterator<Item = (VertexId, VertexId)> + '_ {
        (0..self.num_vertices() as VertexId).flat_map(move |u| {
            self.neighbors(u).iter().map(move |&v| (u, v))
        })
    }

    /// True if every vertex has at least one out-edge (no dead ends). The
    /// paper eliminates dead ends by adding self-loops at load time; the
    /// native engines instead survive dead ends via the teleport fallback
    /// (see `engines::native`).
    pub fn has_no_dead_ends(&self) -> bool {
        (0..self.num_vertices() as VertexId).all(|v| self.degree(v) > 0)
    }

    /// Raw offsets (for packing into device formats).
    #[inline]
    pub fn offsets(&self) -> &[u64] {
        &self.offsets
    }

    /// Raw targets.
    #[inline]
    pub fn targets(&self) -> &[VertexId] {
        &self.targets
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn diamond() -> CsrGraph {
        // 0 -> 1, 0 -> 2, 1 -> 3, 2 -> 3, 3 -> 0
        CsrGraph::from_edges(4, &[(0, 1), (0, 2), (1, 3), (2, 3), (3, 0)])
    }

    #[test]
    fn from_edges_basic() {
        let g = diamond();
        assert_eq!(g.num_vertices(), 4);
        assert_eq!(g.num_edges(), 5);
        assert_eq!(g.neighbors(0), &[1, 2]);
        assert_eq!(g.neighbors(3), &[0]);
        assert_eq!(g.degree(1), 1);
    }

    #[test]
    fn from_adjacency_matches_from_edges() {
        let adj = vec![vec![1, 2], vec![3], vec![3], vec![0]];
        assert_eq!(CsrGraph::from_adjacency(&adj), diamond());
    }

    #[test]
    fn transpose_roundtrip() {
        let g = diamond();
        let gt = g.transpose();
        assert_eq!(gt.neighbors(3), &[1, 2]);
        assert_eq!(gt.neighbors(0), &[3]);
        // double transpose preserves edge multiset per vertex
        let gtt = gt.transpose();
        for v in 0..4 {
            let mut a = g.neighbors(v).to_vec();
            let mut b = gtt.neighbors(v).to_vec();
            a.sort_unstable();
            b.sort_unstable();
            assert_eq!(a, b);
        }
    }

    #[test]
    fn parallel_build_identical_to_sequential() {
        // above the cutoff, with skewed sources so histograms are uneven
        let n = 5_000usize;
        let mut rng = Rng::seed_from_u64(99);
        let edges: Vec<(u32, u32)> = (0..80_000)
            .map(|_| {
                let u = (rng.gen_range(n) * rng.gen_range(n) / n) as u32; // skew
                let v = rng.gen_range(n) as u32;
                (u, v)
            })
            .collect();
        let seq = CsrGraph::from_edges_seq(n, &edges);
        for threads in [2, 3, 4, 8] {
            let parl = CsrGraph::from_edges_threads(n, &edges, threads);
            assert_eq!(parl, seq, "from_edges t={threads}");
            assert_eq!(parl.transpose_threads(threads), seq.transpose_seq(),
                "transpose t={threads}");
        }
    }

    #[test]
    fn dead_end_detection() {
        let g = CsrGraph::from_edges(3, &[(0, 1), (1, 0)]);
        assert!(!g.has_no_dead_ends()); // vertex 2 has no out-edge
        let g2 = CsrGraph::from_edges(3, &[(0, 1), (1, 0), (2, 2)]);
        assert!(g2.has_no_dead_ends());
    }

    #[test]
    fn edges_iterator_counts() {
        let g = diamond();
        let edges: Vec<_> = g.edges().collect();
        assert_eq!(edges.len(), g.num_edges());
        assert!(edges.contains(&(0, 2)));
    }
}
