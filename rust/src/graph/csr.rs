//! Compressed Sparse Row graph storage.
//!
//! The paper computes ranks by pulling over the *transpose* of the current
//! graph (in-neighbors) and expands frontiers by pushing over the graph
//! itself (out-neighbors); [`CsrGraph`] stores one direction and
//! [`CsrGraph::transpose`] produces the other.

use super::VertexId;

/// Immutable CSR adjacency: `targets[offsets[v]..offsets[v+1]]` are the
/// neighbors of `v` (out-neighbors by convention; a transposed instance
/// holds in-neighbors).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CsrGraph {
    offsets: Vec<u64>,
    targets: Vec<VertexId>,
}

impl CsrGraph {
    /// Build from per-vertex adjacency lists.
    pub fn from_adjacency(adj: &[Vec<VertexId>]) -> Self {
        let n = adj.len();
        let mut offsets = Vec::with_capacity(n + 1);
        let mut total = 0u64;
        offsets.push(0);
        for nbrs in adj {
            total += nbrs.len() as u64;
            offsets.push(total);
        }
        let mut targets = Vec::with_capacity(total as usize);
        for nbrs in adj {
            targets.extend_from_slice(nbrs);
        }
        Self { offsets, targets }
    }

    /// Build from an edge list (`n` fixes the vertex count; isolated vertices
    /// get empty rows). Uses a counting pass + placement pass, no sorting.
    pub fn from_edges(n: usize, edges: &[(VertexId, VertexId)]) -> Self {
        let mut counts = vec![0u64; n + 1];
        for &(u, _) in edges {
            counts[u as usize + 1] += 1;
        }
        for i in 0..n {
            counts[i + 1] += counts[i];
        }
        let offsets = counts.clone();
        let mut cursor = counts;
        let mut targets = vec![0 as VertexId; edges.len()];
        for &(u, v) in edges {
            let c = &mut cursor[u as usize];
            targets[*c as usize] = v;
            *c += 1;
        }
        Self { offsets, targets }
    }

    /// Number of vertices.
    #[inline]
    pub fn num_vertices(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Number of (directed) edges, self-loops included.
    #[inline]
    pub fn num_edges(&self) -> usize {
        self.targets.len()
    }

    /// Neighbors of `v`.
    #[inline]
    pub fn neighbors(&self, v: VertexId) -> &[VertexId] {
        let s = self.offsets[v as usize] as usize;
        let e = self.offsets[v as usize + 1] as usize;
        &self.targets[s..e]
    }

    /// Degree of `v` in this direction.
    #[inline]
    pub fn degree(&self, v: VertexId) -> u32 {
        (self.offsets[v as usize + 1] - self.offsets[v as usize]) as u32
    }

    /// All degrees.
    pub fn degrees(&self) -> Vec<u32> {
        (0..self.num_vertices() as VertexId)
            .map(|v| self.degree(v))
            .collect()
    }

    /// Transposed graph (in-neighbors become out-neighbors).
    pub fn transpose(&self) -> CsrGraph {
        let n = self.num_vertices();
        let mut counts = vec![0u64; n + 1];
        for &t in &self.targets {
            counts[t as usize + 1] += 1;
        }
        for i in 0..n {
            counts[i + 1] += counts[i];
        }
        let offsets = counts.clone();
        let mut cursor = counts;
        let mut targets = vec![0 as VertexId; self.targets.len()];
        for u in 0..n {
            for &v in self.neighbors(u as VertexId) {
                let c = &mut cursor[v as usize];
                targets[*c as usize] = u as VertexId;
                *c += 1;
            }
        }
        CsrGraph { offsets, targets }
    }

    /// Iterate all edges `(u, v)`.
    pub fn edges(&self) -> impl Iterator<Item = (VertexId, VertexId)> + '_ {
        (0..self.num_vertices() as VertexId).flat_map(move |u| {
            self.neighbors(u).iter().map(move |&v| (u, v))
        })
    }

    /// True if every vertex has at least one out-edge (no dead ends). The
    /// paper eliminates dead ends by adding self-loops at load time.
    pub fn has_no_dead_ends(&self) -> bool {
        (0..self.num_vertices() as VertexId).all(|v| self.degree(v) > 0)
    }

    /// Raw offsets (for packing into device formats).
    #[inline]
    pub fn offsets(&self) -> &[u64] {
        &self.offsets
    }

    /// Raw targets.
    #[inline]
    pub fn targets(&self) -> &[VertexId] {
        &self.targets
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> CsrGraph {
        // 0 -> 1, 0 -> 2, 1 -> 3, 2 -> 3, 3 -> 0
        CsrGraph::from_edges(4, &[(0, 1), (0, 2), (1, 3), (2, 3), (3, 0)])
    }

    #[test]
    fn from_edges_basic() {
        let g = diamond();
        assert_eq!(g.num_vertices(), 4);
        assert_eq!(g.num_edges(), 5);
        assert_eq!(g.neighbors(0), &[1, 2]);
        assert_eq!(g.neighbors(3), &[0]);
        assert_eq!(g.degree(1), 1);
    }

    #[test]
    fn from_adjacency_matches_from_edges() {
        let adj = vec![vec![1, 2], vec![3], vec![3], vec![0]];
        assert_eq!(CsrGraph::from_adjacency(&adj), diamond());
    }

    #[test]
    fn transpose_roundtrip() {
        let g = diamond();
        let gt = g.transpose();
        assert_eq!(gt.neighbors(3), &[1, 2]);
        assert_eq!(gt.neighbors(0), &[3]);
        // double transpose preserves edge multiset per vertex
        let gtt = gt.transpose();
        for v in 0..4 {
            let mut a = g.neighbors(v).to_vec();
            let mut b = gtt.neighbors(v).to_vec();
            a.sort_unstable();
            b.sort_unstable();
            assert_eq!(a, b);
        }
    }

    #[test]
    fn dead_end_detection() {
        let g = CsrGraph::from_edges(3, &[(0, 1), (1, 0)]);
        assert!(!g.has_no_dead_ends()); // vertex 2 has no out-edge
        let g2 = CsrGraph::from_edges(3, &[(0, 1), (1, 0), (2, 2)]);
        assert!(g2.has_no_dead_ends());
    }

    #[test]
    fn edges_iterator_counts() {
        let g = diamond();
        let edges: Vec<_> = g.edges().collect();
        assert_eq!(edges.len(), g.num_edges());
        assert!(edges.contains(&(0, 2)));
    }
}
