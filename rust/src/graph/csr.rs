//! Compressed Sparse Row graph storage.
//!
//! The paper computes ranks by pulling over the *transpose* of the current
//! graph (in-neighbors) and expands frontiers by pushing over the graph
//! itself (out-neighbors); [`CsrGraph`] stores one direction and
//! [`CsrGraph::transpose`] produces the other.
//!
//! The edge-list and transpose builders run their counting and placement
//! passes on the persistent work-stealing pool (`util::par`): each lane
//! histograms a contiguous edge range, a single fused pass turns the
//! per-lane histograms into row offsets and per-lane write cursors, and
//! placement scatters through [`par::DisjointWriter`] (every edge has a
//! unique precomputed slot). The lane→range mapping is fixed by the input
//! size, so whichever worker steals a lane's task produces the same
//! cursors: each row's neighbor order is the original edge order and the
//! output is *identical* (not just equivalent) to the sequential build at
//! every thread count.

use super::VertexId;
use crate::util::par;

/// Below this many edges the parallel build's histogram setup dominates;
/// run the sequential counting sort.
const PAR_BUILD_CUTOFF: usize = 1 << 15;

/// CSR adjacency. Two layouts share this type:
///
/// * **packed** (`ends == None`, every constructor here): row `v` is
///   `targets[offsets[v]..offsets[v + 1]]`, the arena is gapless and
///   `offsets` is monotone — the layout every engine kernel was written
///   against;
/// * **slack** (`ends == Some`, built only by [`super::dyncsr::DynCsr`]):
///   row `v` is `targets[offsets[v]..ends[v]]` with per-row headroom after
///   `ends[v]`, so a batch insertion is an in-row shift instead of a full
///   rebuild. `offsets` may be non-monotone after a row relocates to the
///   arena tail, and the arena contains dead regions.
///
/// All row-level accessors ([`neighbors`](CsrGraph::neighbors),
/// [`degree`](CsrGraph::degree), [`edges`](CsrGraph::edges), …) work on
/// both layouts; the raw [`offsets`](CsrGraph::offsets) /
/// [`targets`](CsrGraph::targets) slices are only meaningful as a packed
/// row map when [`is_packed`](CsrGraph::is_packed) holds (absolute arena
/// ranges stay valid in both layouts). Equality is *logical*: two graphs
/// compare equal iff every row holds the same neighbor sequence, whatever
/// the layout.
#[derive(Debug, Clone)]
pub struct CsrGraph {
    /// Row starts (`n + 1` entries when packed — the classic offset array —
    /// `n` meaningful entries in slack mode).
    pub(crate) offsets: Vec<u64>,
    pub(crate) targets: Vec<VertexId>,
    /// Per-row ends: `Some` selects the slack layout.
    pub(crate) ends: Option<Vec<u64>>,
    /// Logical edge count (= `targets.len()` when packed).
    pub(crate) m: usize,
    /// Out-degrees as f64, maintained by `DynCsr` so the asynchronous
    /// engines' fused gather-divide skips the O(n) recompute per solve.
    pub(crate) deg_f64_cache: Option<Vec<f64>>,
    /// `(threshold, ascending vertex ids with degree > threshold)`,
    /// maintained by `DynCsr` so `StepPlan::build` skips the O(n)
    /// re-partition per run. Must equal `partition_by_degree(...).high()`.
    pub(crate) hub_cache: Option<(u32, Vec<VertexId>)>,
}

impl PartialEq for CsrGraph {
    /// Logical (per-row) equality, independent of layout and caches: a
    /// slack graph equals its packed rebuild iff every row matches.
    fn eq(&self, other: &Self) -> bool {
        self.num_vertices() == other.num_vertices()
            && self.m == other.m
            && (0..self.num_vertices() as VertexId)
                .all(|v| self.neighbors(v) == other.neighbors(v))
    }
}

impl Eq for CsrGraph {}

/// Fuse per-thread histograms (thread-major, `n` entries each) into row
/// offsets and in-place write cursors: after this, `hists[t*n + v]` is the
/// first target slot for thread `t`'s edges with row `v`, and
/// `offsets[v]` is the start of row `v`. Returns the edge total.
fn cursors_from_histograms(n: usize, hists: &mut [u64], offsets: &mut [u64]) -> u64 {
    let lanes = hists.len() / n;
    let mut acc = 0u64;
    for v in 0..n {
        offsets[v] = acc;
        for t in 0..lanes {
            let h = hists[t * n + v];
            hists[t * n + v] = acc;
            acc += h;
        }
    }
    offsets[n] = acc;
    acc
}

impl CsrGraph {
    /// Assemble a packed-layout graph (gapless arena, monotone offsets).
    pub(crate) fn packed(offsets: Vec<u64>, targets: Vec<VertexId>) -> Self {
        let m = targets.len();
        Self {
            offsets,
            targets,
            ends: None,
            m,
            deg_f64_cache: None,
            hub_cache: None,
        }
    }

    /// Assemble a slack-layout graph (used by `DynCsr`; rows must be sorted
    /// and `m` must equal the sum of row lengths).
    pub(crate) fn slack(offsets: Vec<u64>, ends: Vec<u64>, targets: Vec<VertexId>, m: usize) -> Self {
        Self {
            offsets,
            targets,
            ends: Some(ends),
            m,
            deg_f64_cache: None,
            hub_cache: None,
        }
    }

    /// Build from per-vertex adjacency lists.
    pub fn from_adjacency(adj: &[Vec<VertexId>]) -> Self {
        let n = adj.len();
        let mut offsets = Vec::with_capacity(n + 1);
        let mut total = 0u64;
        offsets.push(0);
        for nbrs in adj {
            total += nbrs.len() as u64;
            offsets.push(total);
        }
        let mut targets = Vec::with_capacity(total as usize);
        for nbrs in adj {
            targets.extend_from_slice(nbrs);
        }
        Self::packed(offsets, targets)
    }

    /// Build from an edge list (`n` fixes the vertex count; isolated vertices
    /// get empty rows). Counting pass + placement pass, no sorting; runs on
    /// the pool for large inputs (`threads = 0` means all cores) with output
    /// identical to the sequential build.
    pub fn from_edges_threads(
        n: usize,
        edges: &[(VertexId, VertexId)],
        threads: usize,
    ) -> Self {
        let threads = par::resolve(threads);
        if threads == 1 || edges.len() < PAR_BUILD_CUTOFF {
            return Self::from_edges_seq(n, edges);
        }
        let chunk = edges.len().div_ceil(threads);
        let lanes = edges.len().div_ceil(chunk);

        // parallel counting: one histogram per contiguous edge range, one
        // pool task per lane (block = n aligns par_for's chunks with the
        // per-lane histograms)
        let mut hists = vec![0u64; lanes * n];
        par::par_for(threads, n, &mut hists, |start, hist| {
            let lo = (start / n) * chunk;
            let hi = (lo + chunk).min(edges.len());
            for &(u, _) in &edges[lo..hi] {
                hist[u as usize] += 1;
            }
        });

        let mut offsets = vec![0u64; n + 1];
        cursors_from_histograms(n, &mut hists, &mut offsets);

        // parallel placement: each lane replays its range against its own
        // cursors; slots are disjoint by construction
        let mut targets = vec![0 as VertexId; edges.len()];
        let writer = par::DisjointWriter::new(&mut targets);
        let writer = &writer;
        par::par_for(threads, n, &mut hists, |start, hist| {
            let lo = (start / n) * chunk;
            let hi = (lo + chunk).min(edges.len());
            for &(u, v) in &edges[lo..hi] {
                let c = &mut hist[u as usize];
                unsafe { writer.write(*c as usize, v) };
                *c += 1;
            }
        });
        Self::packed(offsets, targets)
    }

    /// [`CsrGraph::from_edges_threads`] with the full pool.
    pub fn from_edges(n: usize, edges: &[(VertexId, VertexId)]) -> Self {
        Self::from_edges_threads(n, edges, 0)
    }

    fn from_edges_seq(n: usize, edges: &[(VertexId, VertexId)]) -> Self {
        let mut counts = vec![0u64; n + 1];
        for &(u, _) in edges {
            counts[u as usize + 1] += 1;
        }
        for i in 0..n {
            counts[i + 1] += counts[i];
        }
        let offsets = counts.clone();
        let mut cursor = counts;
        let mut targets = vec![0 as VertexId; edges.len()];
        for &(u, v) in edges {
            let c = &mut cursor[u as usize];
            targets[*c as usize] = v;
            *c += 1;
        }
        Self::packed(offsets, targets)
    }

    /// Number of vertices.
    #[inline]
    pub fn num_vertices(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Number of (directed) edges, self-loops included. In slack layouts
    /// this is the *logical* count, not the arena length.
    #[inline]
    pub fn num_edges(&self) -> usize {
        self.m
    }

    /// `true` for the gapless, monotone-offset layout the raw
    /// [`offsets`](CsrGraph::offsets) array describes completely.
    #[inline]
    pub fn is_packed(&self) -> bool {
        self.ends.is_none()
    }

    /// First arena slot of row `v`.
    #[inline]
    pub(crate) fn row_start(&self, v: usize) -> usize {
        self.offsets[v] as usize
    }

    /// One past the last arena slot of row `v`.
    #[inline]
    pub(crate) fn row_end(&self, v: usize) -> usize {
        match &self.ends {
            Some(e) => e[v] as usize,
            None => self.offsets[v + 1] as usize,
        }
    }

    /// Per-row `(starts, ends)` slices for the SIMD contribution kernel:
    /// `degree(v) = ends[v] - starts[v]`. For packed layouts these are two
    /// windows of the same offset array — exactly the loads the kernel
    /// always did — so the result is bitwise identical across layouts.
    #[inline]
    pub(crate) fn row_bounds(&self) -> (&[u64], &[u64]) {
        let n = self.num_vertices();
        match &self.ends {
            Some(e) => (&self.offsets[..n], e),
            None => (&self.offsets[..n], &self.offsets[1..]),
        }
    }

    /// Neighbors of `v`.
    #[inline]
    pub fn neighbors(&self, v: VertexId) -> &[VertexId] {
        &self.targets[self.row_start(v as usize)..self.row_end(v as usize)]
    }

    /// Degree of `v` in this direction.
    #[inline]
    pub fn degree(&self, v: VertexId) -> u32 {
        (self.row_end(v as usize) - self.row_start(v as usize)) as u32
    }

    /// All degrees.
    pub fn degrees(&self) -> Vec<u32> {
        (0..self.num_vertices() as VertexId)
            .map(|v| self.degree(v))
            .collect()
    }

    /// All degrees as f64 (exact — degrees fit far below 2^52), for the
    /// asynchronous engines' fused gather-divide pull (`util::simd`).
    /// `DynCsr` maintains the cached copy incrementally; packed snapshots
    /// compute it on demand (same integers either way).
    pub fn degrees_f64(&self) -> Vec<f64> {
        if let Some(c) = &self.deg_f64_cache {
            return c.clone();
        }
        (0..self.num_vertices())
            .map(|v| (self.row_end(v) - self.row_start(v)) as f64)
            .collect()
    }

    /// The incrementally-maintained hub list for `threshold`, if this graph
    /// carries one (slack graphs built by `DynCsr`). Identical by contract
    /// to `partition_by_degree(&self.degrees(), threshold).high()`.
    pub(crate) fn cached_hubs(&self, threshold: u32) -> Option<&[VertexId]> {
        match &self.hub_cache {
            Some((t, hubs)) if *t == threshold => Some(hubs),
            _ => None,
        }
    }

    /// Transposed graph (in-neighbors become out-neighbors), built with the
    /// same parallel counting-sort as [`CsrGraph::from_edges_threads`];
    /// identical output at every thread count.
    pub fn transpose_threads(&self, threads: usize) -> CsrGraph {
        if !self.is_packed() {
            // Slack arenas have dead regions the counting passes below would
            // misread; rebuild from the logical edge list instead. Row
            // iteration is ascending-source, so the counting sort places
            // each transpose row in ascending order — matching the sorted
            // rows `DynCsr` maintains directly.
            let rev: Vec<(VertexId, VertexId)> =
                self.edges().map(|(u, v)| (v, u)).collect();
            return CsrGraph::from_edges_threads(self.num_vertices(), &rev, threads);
        }
        let threads = par::resolve(threads);
        let m = self.targets.len();
        if threads == 1 || m < PAR_BUILD_CUTOFF {
            return self.transpose_seq();
        }
        let n = self.num_vertices();
        let chunk = m.div_ceil(threads);
        let lanes = m.div_ceil(chunk);

        // parallel counting over contiguous target ranges
        let targets = &self.targets;
        let mut hists = vec![0u64; lanes * n];
        par::par_for(threads, n, &mut hists, |start, hist| {
            let lo = (start / n) * chunk;
            let hi = (lo + chunk).min(m);
            for &v in &targets[lo..hi] {
                hist[v as usize] += 1;
            }
        });

        let mut toffsets = vec![0u64; n + 1];
        cursors_from_histograms(n, &mut hists, &mut toffsets);

        // parallel placement: each lane walks its edge range, recovering
        // the source row from the forward offsets
        let offsets = &self.offsets;
        let mut ttargets = vec![0 as VertexId; m];
        let writer = par::DisjointWriter::new(&mut ttargets);
        let writer = &writer;
        par::par_for(threads, n, &mut hists, |start, hist| {
            let lo = (start / n) * chunk;
            let hi = (lo + chunk).min(m);
            // last row whose edge range starts at or before lo
            let mut row = offsets.partition_point(|&o| (o as usize) <= lo) - 1;
            let mut idx = lo;
            while idx < hi {
                let row_end = (offsets[row + 1] as usize).min(hi);
                for &v in &targets[idx..row_end] {
                    let c = &mut hist[v as usize];
                    unsafe { writer.write(*c as usize, row as VertexId) };
                    *c += 1;
                }
                idx = row_end;
                row += 1;
            }
        });
        CsrGraph::packed(toffsets, ttargets)
    }

    /// [`CsrGraph::transpose_threads`] with the full pool.
    pub fn transpose(&self) -> CsrGraph {
        self.transpose_threads(0)
    }

    fn transpose_seq(&self) -> CsrGraph {
        let n = self.num_vertices();
        let mut counts = vec![0u64; n + 1];
        for &t in &self.targets {
            counts[t as usize + 1] += 1;
        }
        for i in 0..n {
            counts[i + 1] += counts[i];
        }
        let offsets = counts.clone();
        let mut cursor = counts;
        let mut targets = vec![0 as VertexId; self.targets.len()];
        for u in 0..n {
            for &v in self.neighbors(u as VertexId) {
                let c = &mut cursor[v as usize];
                targets[*c as usize] = u as VertexId;
                *c += 1;
            }
        }
        CsrGraph::packed(offsets, targets)
    }

    /// Iterate all edges `(u, v)`.
    pub fn edges(&self) -> impl Iterator<Item = (VertexId, VertexId)> + '_ {
        (0..self.num_vertices() as VertexId).flat_map(move |u| {
            self.neighbors(u).iter().map(move |&v| (u, v))
        })
    }

    /// True if every vertex has at least one out-edge (no dead ends). The
    /// paper eliminates dead ends by adding self-loops at load time; the
    /// native engines instead survive dead ends via the teleport fallback
    /// (see `engines::native`).
    pub fn has_no_dead_ends(&self) -> bool {
        (0..self.num_vertices() as VertexId).all(|v| self.degree(v) > 0)
    }

    /// Raw offsets (for packing into device formats). Only a complete row
    /// map when [`is_packed`](CsrGraph::is_packed); slack layouts need
    /// `row_start`/`row_end`.
    #[inline]
    pub fn offsets(&self) -> &[u64] {
        &self.offsets
    }

    /// Raw target arena. Absolute ranges from `row_start`/`row_end` (or a
    /// `StepPlan`'s hub items) are valid in both layouts; slack arenas also
    /// contain dead regions between rows.
    #[inline]
    pub fn targets(&self) -> &[VertexId] {
        &self.targets
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn diamond() -> CsrGraph {
        // 0 -> 1, 0 -> 2, 1 -> 3, 2 -> 3, 3 -> 0
        CsrGraph::from_edges(4, &[(0, 1), (0, 2), (1, 3), (2, 3), (3, 0)])
    }

    #[test]
    fn from_edges_basic() {
        let g = diamond();
        assert_eq!(g.num_vertices(), 4);
        assert_eq!(g.num_edges(), 5);
        assert_eq!(g.neighbors(0), &[1, 2]);
        assert_eq!(g.neighbors(3), &[0]);
        assert_eq!(g.degree(1), 1);
    }

    #[test]
    fn from_adjacency_matches_from_edges() {
        let adj = vec![vec![1, 2], vec![3], vec![3], vec![0]];
        assert_eq!(CsrGraph::from_adjacency(&adj), diamond());
    }

    #[test]
    fn transpose_roundtrip() {
        let g = diamond();
        let gt = g.transpose();
        assert_eq!(gt.neighbors(3), &[1, 2]);
        assert_eq!(gt.neighbors(0), &[3]);
        // double transpose preserves edge multiset per vertex
        let gtt = gt.transpose();
        for v in 0..4 {
            let mut a = g.neighbors(v).to_vec();
            let mut b = gtt.neighbors(v).to_vec();
            a.sort_unstable();
            b.sort_unstable();
            assert_eq!(a, b);
        }
    }

    #[test]
    fn parallel_build_identical_to_sequential() {
        // above the cutoff, with skewed sources so histograms are uneven
        let n = 5_000usize;
        let mut rng = Rng::seed_from_u64(99);
        let edges: Vec<(u32, u32)> = (0..80_000)
            .map(|_| {
                let u = (rng.gen_range(n) * rng.gen_range(n) / n) as u32; // skew
                let v = rng.gen_range(n) as u32;
                (u, v)
            })
            .collect();
        let seq = CsrGraph::from_edges_seq(n, &edges);
        for threads in [2, 3, 4, 8] {
            let parl = CsrGraph::from_edges_threads(n, &edges, threads);
            assert_eq!(parl, seq, "from_edges t={threads}");
            assert_eq!(parl.transpose_threads(threads), seq.transpose_seq(),
                "transpose t={threads}");
        }
    }

    #[test]
    fn dead_end_detection() {
        let g = CsrGraph::from_edges(3, &[(0, 1), (1, 0)]);
        assert!(!g.has_no_dead_ends()); // vertex 2 has no out-edge
        let g2 = CsrGraph::from_edges(3, &[(0, 1), (1, 0), (2, 2)]);
        assert!(g2.has_no_dead_ends());
    }

    #[test]
    fn edges_iterator_counts() {
        let g = diamond();
        let edges: Vec<_> = g.edges().collect();
        assert_eq!(edges.len(), g.num_edges());
        assert!(edges.contains(&(0, 2)));
    }
}
