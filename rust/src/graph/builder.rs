//! Mutable adjacency for dynamic graphs: the coordinator applies batch
//! updates here and snapshots to CSR for each PageRank run.
//!
//! Matches the paper's loading protocol (Section 5.1.4): after construction
//! and after every batch update, `ensure_self_loops` eliminates dead ends by
//! giving every vertex a self-loop.

use super::{CsrGraph, VertexId};

/// Mutable out-adjacency with O(log deg) membership tests and duplicate
/// detection (static edge semantics: at most one copy of each (u, v)).
///
/// **Sorted-row invariant:** every adjacency row is kept sorted ascending.
/// This makes `has_edge`/`insert_edge`/`remove_edge` binary searches (hubs
/// in batch validation stop being quadratic) and is the neighbor-order
/// determinism contract: `to_csr()` emits the same sorted rows the
/// incremental [`DynCsr`](super::DynCsr) structure maintains, so ranks are
/// bitwise identical between the rebuild and incremental CSR modes.
#[derive(Debug, Clone, Default)]
pub struct GraphBuilder {
    adj: Vec<Vec<VertexId>>,
    num_edges: usize,
}

impl GraphBuilder {
    /// Empty graph with `n` vertices.
    pub fn new(n: usize) -> Self {
        Self { adj: vec![Vec::new(); n], num_edges: 0 }
    }

    /// Build from an existing edge list, dropping duplicates.
    pub fn from_edges(n: usize, edges: impl IntoIterator<Item = (VertexId, VertexId)>) -> Self {
        let mut b = Self::new(n);
        for (u, v) in edges {
            b.insert_edge(u, v);
        }
        b
    }

    pub fn num_vertices(&self) -> usize {
        self.adj.len()
    }

    pub fn num_edges(&self) -> usize {
        self.num_edges
    }

    pub fn out_neighbors(&self, u: VertexId) -> &[VertexId] {
        &self.adj[u as usize]
    }

    pub fn has_edge(&self, u: VertexId, v: VertexId) -> bool {
        self.adj[u as usize].binary_search(&v).is_ok()
    }

    /// Insert (u, v) in sorted position; returns false if it already
    /// existed. O(log deg) search + O(deg) shift.
    pub fn insert_edge(&mut self, u: VertexId, v: VertexId) -> bool {
        assert!((u as usize) < self.adj.len() && (v as usize) < self.adj.len());
        let row = &mut self.adj[u as usize];
        match row.binary_search(&v) {
            Ok(_) => false,
            Err(pos) => {
                row.insert(pos, v);
                self.num_edges += 1;
                true
            }
        }
    }

    /// Remove (u, v); returns false if it was absent. Self-loops are
    /// protected: they model dead-end elimination and are never removed.
    pub fn remove_edge(&mut self, u: VertexId, v: VertexId) -> bool {
        if u == v {
            return false;
        }
        let row = &mut self.adj[u as usize];
        match row.binary_search(&v) {
            Ok(pos) => {
                // shift, not swap_remove: the sorted-row invariant holds
                row.remove(pos);
                self.num_edges -= 1;
                true
            }
            Err(_) => false,
        }
    }

    /// Add a self-loop to every vertex that lacks one (paper Section 5.1.4:
    /// self-loops are (re-)added alongside every batch update).
    pub fn ensure_self_loops(&mut self) {
        for v in 0..self.adj.len() {
            let vid = v as VertexId;
            if let Err(pos) = self.adj[v].binary_search(&vid) {
                self.adj[v].insert(pos, vid);
                self.num_edges += 1;
            }
        }
    }

    /// Snapshot to immutable CSR.
    pub fn to_csr(&self) -> CsrGraph {
        CsrGraph::from_adjacency(&self.adj)
    }

    /// All edges, in adjacency order.
    pub fn edges(&self) -> impl Iterator<Item = (VertexId, VertexId)> + '_ {
        self.adj.iter().enumerate().flat_map(|(u, vs)| {
            vs.iter().map(move |&v| (u as VertexId, v))
        })
    }

    /// Non-self-loop edges (the candidates for random deletion batches).
    pub fn real_edges(&self) -> Vec<(VertexId, VertexId)> {
        self.edges().filter(|&(u, v)| u != v).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_remove_roundtrip() {
        let mut b = GraphBuilder::new(4);
        assert!(b.insert_edge(0, 1));
        assert!(!b.insert_edge(0, 1)); // duplicate
        assert!(b.insert_edge(1, 2));
        assert_eq!(b.num_edges(), 2);
        assert!(b.remove_edge(0, 1));
        assert!(!b.remove_edge(0, 1)); // absent
        assert_eq!(b.num_edges(), 1);
        assert!(b.has_edge(1, 2));
    }

    #[test]
    fn self_loops_added_once_and_protected() {
        let mut b = GraphBuilder::from_edges(3, [(0, 1), (1, 2)]);
        b.ensure_self_loops();
        assert_eq!(b.num_edges(), 5);
        b.ensure_self_loops(); // idempotent
        assert_eq!(b.num_edges(), 5);
        assert!(!b.remove_edge(2, 2)); // protected
        assert!(b.has_edge(2, 2));
        assert!(b.to_csr().has_no_dead_ends());
    }

    #[test]
    fn rows_stay_sorted_under_churn() {
        let mut b = GraphBuilder::new(8);
        for v in [5u32, 1, 7, 3, 0, 6, 2, 4] {
            b.insert_edge(0, v);
        }
        assert_eq!(b.out_neighbors(0), &[0, 1, 2, 3, 4, 5, 6, 7]);
        b.remove_edge(0, 3);
        b.ensure_self_loops();
        assert_eq!(b.out_neighbors(0), &[0, 1, 2, 4, 5, 6, 7]);
        for w in 1..8u32 {
            assert!(b.out_neighbors(w).windows(2).all(|p| p[0] < p[1]));
        }
    }

    #[test]
    fn csr_snapshot_matches() {
        let mut b = GraphBuilder::from_edges(3, [(0, 1), (1, 2), (2, 0)]);
        b.ensure_self_loops();
        let g = b.to_csr();
        assert_eq!(g.num_edges(), b.num_edges());
        for v in 0..3u32 {
            let mut a = b.out_neighbors(v).to_vec();
            let mut c = g.neighbors(v).to_vec();
            a.sort_unstable();
            c.sort_unstable();
            assert_eq!(a, c);
        }
    }
}
