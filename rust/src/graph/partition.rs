//! Parallel vertex partitioning by degree — the paper's Algorithm 4.
//!
//! Splits vertex ids into a low-degree prefix and a high-degree suffix via
//! two exclusive prefix-sum passes (exactly the paper's formulation: a
//! boolean buffer, an exclusive scan, and a placement pass — all parallel).
//! The device engines partition by in-degree for rank computation and by
//! out-degree for frontier expansion; the native engine uses it for work
//! scheduling, and the packers in `runtime::tier` use it to route vertices
//! between the ELL ("thread-per-vertex") and hub-chunk ("block-per-vertex")
//! kernels.

use super::VertexId;

/// Result of Algorithm 4: `ids` holds all vertex ids with the `n_low`
/// low-degree ones first.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Partition {
    pub ids: Vec<VertexId>,
    pub n_low: usize,
}

impl Partition {
    pub fn low(&self) -> &[VertexId] {
        &self.ids[..self.n_low]
    }

    pub fn high(&self) -> &[VertexId] {
        &self.ids[self.n_low..]
    }
}

/// Exclusive prefix sum, in place; returns the total.
fn exclusive_scan(buf: &mut [u64]) -> u64 {
    let mut acc = 0u64;
    for x in buf.iter_mut() {
        let v = *x;
        *x = acc;
        acc += v;
    }
    acc
}

/// Partition vertex ids by `degrees[v] <= threshold` (Algorithm 4).
///
/// Two passes per class: populate a 0/1 buffer, exclusive-scan it, then
/// place ids at their scanned positions. (Single-core testbed: the parallel
/// populate/placement passes of the paper's Algorithm 4 degenerate to plain
/// loops; the scan is sequential either way.)
pub fn partition_by_degree(degrees: &[u32], threshold: u32) -> Partition {
    let n = degrees.len();
    let mut buf: Vec<u64> = vec![0; n];

    // low-degree class
    for (b, &d) in buf.iter_mut().zip(degrees.iter()) {
        *b = (d <= threshold) as u64;
    }
    let mut low_pos = buf.clone();
    let n_low = exclusive_scan(&mut low_pos) as usize;

    // high-degree class
    for (b, &d) in buf.iter_mut().zip(degrees.iter()) {
        *b = (d > threshold) as u64;
    }
    let mut high_pos = buf;
    exclusive_scan(&mut high_pos);

    let mut ids = vec![0 as VertexId; n];
    // placement: every vertex has a unique target slot
    for v in 0..n {
        if degrees[v] <= threshold {
            ids[low_pos[v] as usize] = v as VertexId;
        } else {
            ids[n_low + high_pos[v] as usize] = v as VertexId;
        }
    }
    Partition { ids, n_low }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partitions_by_threshold() {
        let degrees = vec![1, 20, 3, 17, 16, 0];
        let p = partition_by_degree(&degrees, 16);
        assert_eq!(p.n_low, 4);
        assert_eq!(p.low(), &[0, 2, 4, 5]);
        assert_eq!(p.high(), &[1, 3]);
    }

    #[test]
    fn all_low_or_all_high() {
        let degrees = vec![2, 2, 2];
        let p = partition_by_degree(&degrees, 16);
        assert_eq!(p.n_low, 3);
        assert_eq!(p.high(), &[] as &[VertexId]);
        let p = partition_by_degree(&degrees, 1);
        assert_eq!(p.n_low, 0);
        assert_eq!(p.high(), &[0, 1, 2]);
    }

    #[test]
    fn is_permutation() {
        let degrees: Vec<u32> = (0..1000).map(|i| (i * 7919) % 40).collect();
        let p = partition_by_degree(&degrees, 16);
        let mut ids = p.ids.clone();
        ids.sort_unstable();
        assert!(ids.iter().enumerate().all(|(i, &v)| i as u32 == v));
        // stability within classes: ids ascending in each class
        assert!(p.low().windows(2).all(|w| w[0] < w[1]));
        assert!(p.high().windows(2).all(|w| w[0] < w[1]));
    }
}
