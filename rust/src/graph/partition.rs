//! Parallel vertex partitioning by degree — the paper's Algorithm 4.
//!
//! Splits vertex ids into a low-degree prefix and a high-degree suffix via
//! two exclusive prefix-sum passes (exactly the paper's formulation: a
//! boolean buffer, an exclusive scan, and a placement pass — all parallel).
//! The device engines partition by in-degree for rank computation and by
//! out-degree for frontier expansion; the native engine uses it for work
//! scheduling, and the packers in `runtime::tier` use it to route vertices
//! between the ELL ("thread-per-vertex") and hub-chunk ("block-per-vertex")
//! kernels.
//!
//! On the persistent work-stealing pool, populate and placement are
//! blocked parallel-for passes and the scan is the classic three-phase
//! blocked exclusive scan (per-chunk totals in parallel, a sequential scan
//! over the chunk totals, then parallel per-chunk offset scans). All
//! arithmetic is integral and chunk boundaries depend only on the input
//! size, so the result is identical at every thread count and under every
//! steal schedule.

use super::VertexId;
use crate::util::par;

/// Below this many vertices the sequential passes win outright.
const PAR_PARTITION_CUTOFF: usize = 1 << 15;

/// Result of Algorithm 4: `ids` holds all vertex ids with the `n_low`
/// low-degree ones first.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Partition {
    pub ids: Vec<VertexId>,
    pub n_low: usize,
}

impl Partition {
    pub fn low(&self) -> &[VertexId] {
        &self.ids[..self.n_low]
    }

    pub fn high(&self) -> &[VertexId] {
        &self.ids[self.n_low..]
    }
}

/// Exclusive prefix sum, in place; returns the total. Sequential reference.
fn exclusive_scan(buf: &mut [u64]) -> u64 {
    let mut acc = 0u64;
    for x in buf.iter_mut() {
        let v = *x;
        *x = acc;
        acc += v;
    }
    acc
}

/// Blocked parallel exclusive prefix sum, in place; returns the total.
/// Phase 1 sums each contiguous chunk in parallel, phase 2 exclusive-scans
/// the chunk totals sequentially, phase 3 rescans each chunk in parallel
/// seeded with its chunk offset.
pub(crate) fn exclusive_scan_threads(buf: &mut [u64], threads: usize) -> u64 {
    let threads = par::resolve(threads);
    if threads == 1 || buf.len() < PAR_PARTITION_CUTOFF {
        return exclusive_scan(buf);
    }
    let chunk = buf.len().div_ceil(threads);
    let nchunks = buf.len().div_ceil(chunk);

    // phase 1: per-chunk totals, one pool task per chunk
    let mut totals = vec![0u64; nchunks];
    {
        let buf = &*buf;
        par::par_for(threads, 1, &mut totals, |start, slot| {
            let lo = start * chunk;
            let hi = (lo + chunk).min(buf.len());
            slot[0] = buf[lo..hi].iter().sum();
        });
    }
    let total = exclusive_scan(&mut totals);

    // phase 3: rescan each chunk seeded with its offset (par_for's blocks
    // coincide with the phase-1 chunks because block = chunk)
    par::par_for(threads, chunk, buf, |start, part| {
        let mut acc = totals[start / chunk];
        for x in part.iter_mut() {
            let v = *x;
            *x = acc;
            acc += v;
        }
    });
    total
}

/// Partition vertex ids by `degrees[v] <= threshold` (Algorithm 4) on the
/// work-stealing pool (`threads = 0` means all cores; small inputs and
/// `threads = 1` run the same passes sequentially, with identical results).
pub fn partition_by_degree_threads(
    degrees: &[u32],
    threshold: u32,
    threads: usize,
) -> Partition {
    let threads = par::resolve(threads);
    let n = degrees.len();

    // populate the low-degree 0/1 buffer (parallel blocked pass)
    let mut low_pos: Vec<u64> = vec![0; n];
    par::par_for(threads, par::DEFAULT_BLOCK, &mut low_pos, |start, out| {
        for (i, b) in out.iter_mut().enumerate() {
            *b = (degrees[start + i] <= threshold) as u64;
        }
    });
    // the high-degree buffer is its complement
    let mut high_pos: Vec<u64> = vec![0; n];
    par::par_for(threads, par::DEFAULT_BLOCK, &mut high_pos, |start, out| {
        for (i, b) in out.iter_mut().enumerate() {
            *b = (degrees[start + i] > threshold) as u64;
        }
    });

    let n_low = exclusive_scan_threads(&mut low_pos, threads) as usize;
    exclusive_scan_threads(&mut high_pos, threads);

    // placement: every vertex has a unique target slot
    let mut ids = vec![0 as VertexId; n];
    let writer = par::DisjointWriter::new(&mut ids);
    let writer = &writer;
    par::par_for_index(threads, par::DEFAULT_BLOCK, n, |start, end| {
        for v in start..end {
            let slot = if degrees[v] <= threshold {
                low_pos[v] as usize
            } else {
                n_low + high_pos[v] as usize
            };
            unsafe { writer.write(slot, v as VertexId) };
        }
    });
    Partition { ids, n_low }
}

/// [`partition_by_degree_threads`] with the full pool.
pub fn partition_by_degree(degrees: &[u32], threshold: u32) -> Partition {
    partition_by_degree_threads(degrees, threshold, 0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partitions_by_threshold() {
        let degrees = vec![1, 20, 3, 17, 16, 0];
        let p = partition_by_degree(&degrees, 16);
        assert_eq!(p.n_low, 4);
        assert_eq!(p.low(), &[0, 2, 4, 5]);
        assert_eq!(p.high(), &[1, 3]);
    }

    #[test]
    fn all_low_or_all_high() {
        let degrees = vec![2, 2, 2];
        let p = partition_by_degree(&degrees, 16);
        assert_eq!(p.n_low, 3);
        assert_eq!(p.high(), &[] as &[VertexId]);
        let p = partition_by_degree(&degrees, 1);
        assert_eq!(p.n_low, 0);
        assert_eq!(p.high(), &[0, 1, 2]);
    }

    #[test]
    fn is_permutation() {
        let degrees: Vec<u32> = (0..1000).map(|i| (i * 7919) % 40).collect();
        let p = partition_by_degree(&degrees, 16);
        let mut ids = p.ids.clone();
        ids.sort_unstable();
        assert!(ids.iter().enumerate().all(|(i, &v)| i as u32 == v));
        // stability within classes: ids ascending in each class
        assert!(p.low().windows(2).all(|w| w[0] < w[1]));
        assert!(p.high().windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn parallel_scan_matches_sequential() {
        // above the cutoff so the three-phase path actually runs
        let vals: Vec<u64> = (0..40_000u64).map(|i| (i * 2654435761) % 97).collect();
        let mut want = vals.clone();
        let want_total = exclusive_scan(&mut want);
        for threads in [2, 3, 4, 8] {
            let mut got = vals.clone();
            let total = exclusive_scan_threads(&mut got, threads);
            assert_eq!(total, want_total, "t={threads}");
            assert_eq!(got, want, "t={threads}");
        }
    }

    #[test]
    fn parallel_partition_matches_sequential_large() {
        let degrees: Vec<u32> = (0..50_000).map(|i| ((i * 7919) % 4000) as u32).collect();
        let want = partition_by_degree_threads(&degrees, 1024, 1);
        for threads in [2, 4, 8] {
            let got = partition_by_degree_threads(&degrees, 1024, threads);
            assert_eq!(got, want, "t={threads}");
        }
    }
}
