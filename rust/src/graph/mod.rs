//! Graph substrates: CSR storage, a mutable builder for dynamic updates,
//! and degree partitioning (the paper's Algorithm 4).

pub mod builder;
pub mod csr;
pub mod dyncsr;
pub mod partition;

pub use builder::GraphBuilder;
pub use csr::CsrGraph;
pub use dyncsr::{CsrMode, DynCsr};
pub use partition::{partition_by_degree, Partition};

/// Vertex ids are 32-bit, as in the paper (Section 5.1.2).
pub type VertexId = u32;
