//! Benchmark harness: regenerates every table and figure of the paper's
//! evaluation (see DESIGN.md §5 for the experiment index). Invoked via
//! `pagerank-dynamic bench --exp <id>`.

pub mod experiments;
pub mod report;

pub use report::{fmt_dur, geomean, Report};
