//! Report rendering: aligned console tables (the paper's rows/series) and
//! JSON result files under `bench_results/`.

use std::path::Path;

use crate::util::json::quote;

/// A rendered experiment: console table + machine-readable rows.
#[derive(Debug)]
pub struct Report {
    pub experiment: String,
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
    /// Free-form notes (substitutions, caveats).
    pub notes: Vec<String>,
}

impl Report {
    pub fn new(experiment: &str, title: &str, columns: &[&str]) -> Self {
        Self {
            experiment: experiment.to_string(),
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len());
        self.rows.push(cells);
    }

    pub fn note(&mut self, s: impl Into<String>) {
        self.notes.push(s.into());
    }

    /// Render an aligned console table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} — {} ==\n", self.experiment, self.title));
        let header: Vec<String> = self
            .columns
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
            .collect();
        out.push_str(&header.join("  "));
        out.push('\n');
        out.push_str(&"-".repeat(header.join("  ").len()));
        out.push('\n');
        for row in &self.rows {
            let line: Vec<String> = row
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect();
            out.push_str(&line.join("  "));
            out.push('\n');
        }
        for n in &self.notes {
            out.push_str(&format!("note: {n}\n"));
        }
        out
    }

    /// Serialize to JSON (hand-rolled; offline build has no serde).
    pub fn to_json(&self) -> String {
        let arr = |xs: &[String]| {
            format!("[{}]", xs.iter().map(|x| quote(x)).collect::<Vec<_>>().join(", "))
        };
        let rows = self
            .rows
            .iter()
            .map(|r| format!("    {}", arr(r)))
            .collect::<Vec<_>>()
            .join(",\n");
        format!(
            "{{\n  \"experiment\": {},\n  \"title\": {},\n  \"columns\": {},\n  \"rows\": [\n{}\n  ],\n  \"notes\": {}\n}}\n",
            quote(&self.experiment),
            quote(&self.title),
            arr(&self.columns),
            rows,
            arr(&self.notes),
        )
    }

    /// Print to stdout and persist JSON under `dir`.
    pub fn emit(&self, dir: &Path) -> anyhow::Result<()> {
        println!("{}", self.render());
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{}.json", self.experiment));
        std::fs::write(&path, self.to_json())?;
        Ok(())
    }
}

/// Format a Duration in human units (µs/ms/s).
pub fn fmt_dur(d: std::time::Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.0}µs", s * 1e6)
    }
}

/// Geometric mean of positive samples.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.max(1e-300).ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_table() {
        let mut r = Report::new("test", "Title", &["graph", "time"]);
        r.row(vec!["sk-2005".into(), "4.2s".into()]);
        r.row(vec!["x".into(), "10ms".into()]);
        let s = r.render();
        assert!(s.contains("sk-2005"));
        assert!(s.lines().count() >= 5);
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn fmt_durations() {
        use std::time::Duration;
        assert_eq!(fmt_dur(Duration::from_secs(2)), "2.00s");
        assert_eq!(fmt_dur(Duration::from_millis(5)), "5.00ms");
        assert_eq!(fmt_dur(Duration::from_micros(7)), "7µs");
    }
}
