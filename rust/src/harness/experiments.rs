//! Experiment runners: one per table/figure of the paper (DESIGN.md §5).
//!
//! Every runner prints the paper's rows/series as an aligned table and
//! writes `bench_results/<exp>.json`. Absolute numbers are testbed numbers
//! (XLA-CPU "GPU", scoped-thread-pool CPU); the *shape* — which approach wins, by what
//! factor, where crossovers fall — is the reproduction target, and
//! EXPERIMENTS.md records paper-vs-measured side by side.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::batch::{self, BatchUpdate};
use crate::engines::baselines::{gunrock_like, hornet_like};
use crate::engines::config::PagerankConfig;
use crate::engines::device::{DeviceEngine, PartitionMode};
use crate::engines::error::l1_distance;
use crate::engines::{native, Approach, PagerankResult};
use crate::generators::{families, Dataset, DATASETS};
use crate::graph::{CsrGraph, GraphBuilder};
use crate::runtime::{ArtifactStore, DeviceGraph};
use crate::temporal;

use super::report::{fmt_dur, geomean, Report};

/// Harness options.
#[derive(Debug, Clone)]
pub struct ExpOptions {
    /// Reduced sweeps (fewer batches, looser reference tolerance) so the
    /// whole suite completes in minutes; `--full` restores the paper's
    /// protocol (100 batches, tau_ref = 1e-100/500 iters).
    pub quick: bool,
    pub out_dir: PathBuf,
}

impl Default for ExpOptions {
    fn default() -> Self {
        Self { quick: true, out_dir: PathBuf::from("bench_results") }
    }
}

impl ExpOptions {
    fn reference_cfg(&self) -> PagerankConfig {
        if self.quick {
            // converges in ~140 iterations; error floor ~1e-13 — two orders
            // below anything the experiments compare.
            PagerankConfig { tau: 1e-14, ..PagerankConfig::default() }
        } else {
            PagerankConfig::reference()
        }
    }

    fn num_batches(&self) -> usize {
        if self.quick {
            5
        } else {
            100
        }
    }
}

/// Engine substrate for a run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Substrate {
    /// AOT artifacts on PJRT — the paper's GPU.
    Device,
    /// Scoped-thread-pool multicore (`util::par`) — the paper's CPU
    /// comparator.
    Native,
}

/// Shared runner: dispatches (approach, substrate) against a graph snapshot.
pub struct Runner {
    pub store: Option<Arc<ArtifactStore>>,
    pub cfg: PagerankConfig,
}

impl Runner {
    pub fn run(
        &self,
        approach: Approach,
        substrate: Substrate,
        g: &CsrGraph,
        gt: &CsrGraph,
        g_old: &CsrGraph,
        prev: Option<&[f64]>,
        batch: &BatchUpdate,
    ) -> Result<PagerankResult> {
        match substrate {
            Substrate::Device => {
                let Some(store) = &self.store else {
                    bail!("device substrate requires artifacts (run `make artifacts`)")
                };
                let dg = store.pack_graph(g, gt)?;
                DeviceEngine::new(store).run_approach(
                    approach, &dg, g, g_old, &self.cfg, prev, batch,
                )
            }
            Substrate::Native => Ok(match approach {
                Approach::Static => native::static_pagerank(g, gt, &self.cfg, None),
                Approach::NaiveDynamic => {
                    native::naive_dynamic(g, gt, &self.cfg, prev.expect("prev"))
                }
                Approach::DynamicTraversal => native::dynamic::dynamic_traversal(
                    g, gt, g_old, &self.cfg, prev.expect("prev"), batch,
                ),
                Approach::DynamicFrontier => native::dynamic::dynamic_frontier(
                    g, gt, &self.cfg, prev.expect("prev"), batch, false,
                ),
                Approach::DynamicFrontierPruning => native::dynamic::dynamic_frontier(
                    g, gt, &self.cfg, prev.expect("prev"), batch, true,
                ),
            }),
        }
    }
}

/// Per-approach outcome of a batch-update series.
#[derive(Debug, Default, Clone)]
pub struct SeriesOutcome {
    pub times: Vec<f64>,
    pub errors: Vec<f64>,
    pub iterations: Vec<usize>,
}

impl SeriesOutcome {
    pub fn mean_time(&self) -> f64 {
        geomean(&self.times)
    }
    pub fn mean_error(&self) -> f64 {
        self.errors.iter().sum::<f64>() / self.errors.len().max(1) as f64
    }
}

/// Run a sequence of batch updates through several approaches, each keeping
/// its own rank state (the paper's measurement protocol): per batch, the
/// graph is updated once, a reference static run defines the truth, and
/// every approach refreshes its ranks from its own previous output.
#[allow(clippy::too_many_arguments)]
pub fn run_batch_series(
    runner: &Runner,
    base: &GraphBuilder,
    batches: &[BatchUpdate],
    approaches: &[Approach],
    substrate: Substrate,
    ref_cfg: &PagerankConfig,
) -> Result<HashMap<Approach, SeriesOutcome>> {
    let mut b = base.clone();
    let g0 = b.to_csr();
    let gt0 = g0.transpose();
    let init = native::static_pagerank(&g0, &gt0, &runner.cfg, None).ranks;

    let mut prev: HashMap<Approach, Vec<f64>> =
        approaches.iter().map(|&a| (a, init.clone())).collect();
    let mut out: HashMap<Approach, SeriesOutcome> =
        approaches.iter().map(|&a| (a, SeriesOutcome::default())).collect();

    for upd in batches {
        let old_csr = b.to_csr();
        batch::apply(&mut b, upd);
        let g = b.to_csr();
        let gt = g.transpose();
        let reference = native::static_pagerank(&g, &gt, ref_cfg, None).ranks;

        for &a in approaches {
            let res = runner.run(a, substrate, &g, &gt, &old_csr, Some(&prev[&a]), upd)?;
            let o = out.get_mut(&a).unwrap();
            o.times.push(res.elapsed.as_secs_f64());
            o.errors.push(l1_distance(&res.ranks, &reference)?);
            o.iterations.push(res.iterations);
            prev.insert(a, res.ranks);
        }
    }
    Ok(out)
}

fn quick_datasets(opts: &ExpOptions) -> Vec<&'static Dataset> {
    if opts.quick {
        ["it-2004", "sk-2005", "com-LiveJournal", "com-Orkut", "asia_osm", "kmer_A2a"]
            .iter()
            .map(|n| families::dataset(n).unwrap())
            .collect()
    } else {
        DATASETS.iter().collect()
    }
}

fn temporal_graphs(opts: &ExpOptions) -> Vec<temporal::TemporalGraph> {
    let mut g = temporal::table3_standins();
    if opts.quick {
        g.truncate(4); // drop the 800k-event stackoverflow stand-in
    }
    g
}

// ---------------------------------------------------------------------------
// Table 1 / Figure 2: Static PageRank vs Hornet-like / Gunrock-like
// ---------------------------------------------------------------------------

pub fn exp_table1_fig2(runner: &Runner, opts: &ExpOptions) -> Result<()> {
    let mut rep = Report::new(
        "table1_fig2",
        "Static PageRank runtime & speedup vs Hornet-like / Gunrock-like baselines",
        &[
            "graph", "n", "m", "hornet", "gunrock", "ours-CPU", "ours-GPU",
            "A100 model", "vs hornet", "vs gunrock", "GPU vs CPU",
        ],
    );
    rep.note(
        "baselines are structural reimplementations of Hornet/Gunrock's \
         algorithmic choices on this testbed (DESIGN.md §3); paper: 31x vs \
         Hornet, 5.9x vs Gunrock, 24x GPU vs our CPU",
    );
    rep.note(
        "A100 model = bandwidth cost model (costmodel/) at the paper's \
         testbed scale; the XLA-CPU 'GPU' measures algorithm structure, not \
         A100 silicon — relative baseline ordering is the reproduced claim",
    );
    let cfg = &runner.cfg;
    let (mut sp_h, mut sp_g, mut sp_c) = (vec![], vec![], vec![]);
    for d in quick_datasets(opts) {
        let b = d.build();
        let g = b.to_csr();
        let gt = g.transpose();
        let hornet = hornet_like(&g, cfg);
        let gunrock = gunrock_like(&g, cfg);
        let ours_cpu = native::static_pagerank(&g, &gt, cfg, None);
        let ours_gpu = runner.run(
            Approach::Static,
            Substrate::Device,
            &g,
            &gt,
            &g,
            None,
            &BatchUpdate::default(),
        )?;
        let t_ref = ours_gpu.elapsed.as_secs_f64();
        let modeled = crate::costmodel::model_full_run(
            g.num_vertices(),
            g.num_edges(),
            ours_gpu.iterations,
        );
        sp_h.push(hornet.elapsed.as_secs_f64() / t_ref);
        sp_g.push(gunrock.elapsed.as_secs_f64() / t_ref);
        sp_c.push(ours_cpu.elapsed.as_secs_f64() / t_ref);
        rep.row(vec![
            d.name.into(),
            g.num_vertices().to_string(),
            g.num_edges().to_string(),
            fmt_dur(hornet.elapsed),
            fmt_dur(gunrock.elapsed),
            fmt_dur(ours_cpu.elapsed),
            fmt_dur(ours_gpu.elapsed),
            fmt_dur(modeled),
            format!("{:.1}x", hornet.elapsed.as_secs_f64() / t_ref),
            format!("{:.1}x", gunrock.elapsed.as_secs_f64() / t_ref),
            format!("{:.1}x", ours_cpu.elapsed.as_secs_f64() / t_ref),
        ]);
    }
    rep.row(vec![
        "geomean".into(),
        "".into(),
        "".into(),
        "".into(),
        "".into(),
        "".into(),
        "".into(),
        "".into(),
        format!("{:.1}x", geomean(&sp_h)),
        format!("{:.1}x", geomean(&sp_g)),
        format!("{:.1}x", geomean(&sp_c)),
    ]);
    rep.emit(&opts.out_dir)
}

// ---------------------------------------------------------------------------
// Figure 1: work-partitioning ablation for DF / DF-P
// ---------------------------------------------------------------------------

pub fn exp_fig1(runner: &Runner, opts: &ExpOptions) -> Result<()> {
    let Some(store) = &runner.store else { bail!("fig1 needs artifacts") };
    let modes = [
        PartitionMode::DontPartition,
        PartitionMode::PartitionGPrime,
        PartitionMode::PartitionBoth,
        PartitionMode::PartitionBothPull,
    ];
    let mut rep = Report::new(
        "fig1",
        "Mean relative runtime of DF / DF-P across work-partitioning levels",
        &["mode", "DF", "DF-P", "DF rel", "DF-P rel"],
    );
    rep.note("paper: Partition G, G' is fastest; relative runtime normalized to it");

    let mut totals: HashMap<(PartitionMode, bool), Vec<f64>> = HashMap::new();
    for d in quick_datasets(opts).iter().take(4) {
        let mut b = d.build();
        let g0 = b.to_csr();
        let gt0 = g0.transpose();
        let prev = native::static_pagerank(&g0, &gt0, &runner.cfg, None).ranks;
        let upd = batch::random_batch(&b, (g0.num_edges() / 10_000).max(8), 0.8, 77);
        batch::apply(&mut b, &upd);
        let g = b.to_csr();
        let gt = g.transpose();
        let tier = store.tier_for(g.num_vertices(), g.num_edges()).unwrap();
        let dg = DeviceGraph::pack(&g, &gt, &tier)?;
        let eng = DeviceEngine::new(store);
        for mode in modes {
            for prune in [false, true] {
                let res = eng.dynamic_frontier(
                    &dg, &g, &runner.cfg, &prev, &upd, prune, mode, false,
                )?;
                totals
                    .entry((mode, prune))
                    .or_default()
                    .push(res.elapsed.as_secs_f64());
            }
        }
    }
    let best_df = geomean(&totals[&(PartitionMode::PartitionBoth, false)]);
    let best_dfp = geomean(&totals[&(PartitionMode::PartitionBoth, true)]);
    for mode in modes {
        let df = geomean(&totals[&(mode, false)]);
        let dfp = geomean(&totals[&(mode, true)]);
        rep.row(vec![
            mode.label().into(),
            fmt_dur(Duration::from_secs_f64(df)),
            fmt_dur(Duration::from_secs_f64(dfp)),
            format!("{:.2}", df / best_df),
            format!("{:.2}", dfp / best_dfp),
        ]);
    }
    rep.emit(&opts.out_dir)
}

// ---------------------------------------------------------------------------
// Figures 3 & 6: real-world dynamic graphs (temporal stand-ins)
// ---------------------------------------------------------------------------

pub fn exp_fig3(runner: &Runner, opts: &ExpOptions, substrate: Substrate) -> Result<()> {
    let exp = match substrate {
        Substrate::Device => "fig3",
        Substrate::Native => "fig6_cpu",
    };
    let fracs: &[f64] = &[1e-5, 1e-4, 1e-3];
    let mut rep = Report::new(
        exp,
        "Runtime & L1 error on real-world dynamic graphs (per batch fraction of |E_T|)",
        &["graph", "B/|E_T|", "Static", "ND", "DT", "DF", "DF-P",
          "err ND", "err DT", "err DF", "err DF-P", "DF-P speedup"],
    );
    rep.note("synthetic Table-3 stand-ins (DESIGN.md §3); speedup = Static/DF-P");
    let ref_cfg = opts.reference_cfg();

    let mut agg: HashMap<(usize, Approach), Vec<f64>> = HashMap::new();
    for tg in temporal_graphs(opts) {
        for (fi, &frac) in fracs.iter().enumerate() {
            let bsize = ((tg.num_temporal_edges() as f64 * frac).round() as usize).max(1);
            let (base, batches) = tg.replay(bsize, opts.num_batches());
            let out = run_batch_series(
                runner,
                &base,
                &batches,
                &Approach::ALL,
                substrate,
                &ref_cfg,
            )?;
            for &a in &Approach::ALL {
                agg.entry((fi, a)).or_default().extend(&out[&a].times);
            }
            let t = |a: Approach| out[&a].mean_time();
            let e = |a: Approach| out[&a].mean_error();
            rep.row(vec![
                tg.name.clone(),
                format!("{frac:.0e}"),
                fmt_dur(Duration::from_secs_f64(t(Approach::Static))),
                fmt_dur(Duration::from_secs_f64(t(Approach::NaiveDynamic))),
                fmt_dur(Duration::from_secs_f64(t(Approach::DynamicTraversal))),
                fmt_dur(Duration::from_secs_f64(t(Approach::DynamicFrontier))),
                fmt_dur(Duration::from_secs_f64(t(Approach::DynamicFrontierPruning))),
                format!("{:.1e}", e(Approach::NaiveDynamic)),
                format!("{:.1e}", e(Approach::DynamicTraversal)),
                format!("{:.1e}", e(Approach::DynamicFrontier)),
                format!("{:.1e}", e(Approach::DynamicFrontierPruning)),
                format!(
                    "{:.1}x",
                    t(Approach::Static) / t(Approach::DynamicFrontierPruning)
                ),
            ]);
        }
    }
    for (fi, &frac) in fracs.iter().enumerate() {
        let t = |a: Approach| geomean(&agg[&(fi, a)]);
        rep.row(vec![
            "OVERALL".into(),
            format!("{frac:.0e}"),
            fmt_dur(Duration::from_secs_f64(t(Approach::Static))),
            fmt_dur(Duration::from_secs_f64(t(Approach::NaiveDynamic))),
            fmt_dur(Duration::from_secs_f64(t(Approach::DynamicTraversal))),
            fmt_dur(Duration::from_secs_f64(t(Approach::DynamicFrontier))),
            fmt_dur(Duration::from_secs_f64(t(Approach::DynamicFrontierPruning))),
            "".into(),
            "".into(),
            "".into(),
            "".into(),
            format!(
                "{:.1}x",
                t(Approach::Static) / t(Approach::DynamicFrontierPruning)
            ),
        ]);
    }
    rep.emit(&opts.out_dir)
}

// ---------------------------------------------------------------------------
// Figures 4, 5 & 7, 8: large graphs with random batch updates
// ---------------------------------------------------------------------------

pub fn exp_fig4_5(runner: &Runner, opts: &ExpOptions, substrate: Substrate) -> Result<()> {
    let exp = match substrate {
        Substrate::Device => "fig4_5",
        Substrate::Native => "fig7_8_cpu",
    };
    let fracs: &[f64] = if opts.quick {
        &[1e-6, 1e-5, 1e-4, 1e-3, 1e-2]
    } else {
        &[1e-7, 1e-6, 1e-5, 1e-4, 1e-3, 1e-2, 1e-1]
    };
    let repeats = if opts.quick { 2 } else { 5 };
    let mut rep = Report::new(
        exp,
        "Runtime & L1 error on large static graphs with random batch updates (80% ins / 20% del)",
        &["graph", "B/|E|", "Static", "ND", "DT", "DF", "DF-P",
          "err DF", "err DF-P", "DF-P vs Static", "DF-P vs DT"],
    );
    rep.note("synthetic Table-4 stand-ins; batches re-generated per repeat");
    let ref_cfg = opts.reference_cfg();

    let mut agg: HashMap<(usize, Approach), Vec<f64>> = HashMap::new();
    for d in quick_datasets(opts) {
        let base = d.build();
        let m = base.num_edges();
        for (fi, &frac) in fracs.iter().enumerate() {
            let bsize = ((m as f64 * frac).round() as usize).max(1);
            let batches: Vec<BatchUpdate> = (0..repeats)
                .map(|i| batch::random_batch(&base, bsize, 0.8, d.seed * 1000 + fi as u64 * 10 + i))
                .collect();
            // independent batches against the same base graph (the paper
            // averages multiple random batches per size)
            let mut times: HashMap<Approach, Vec<f64>> = HashMap::new();
            let mut errs: HashMap<Approach, Vec<f64>> = HashMap::new();
            for upd in &batches {
                let out = run_batch_series(
                    runner,
                    &base,
                    std::slice::from_ref(upd),
                    &Approach::ALL,
                    substrate,
                    &ref_cfg,
                )?;
                for &a in &Approach::ALL {
                    times.entry(a).or_default().extend(&out[&a].times);
                    errs.entry(a).or_default().extend(&out[&a].errors);
                }
            }
            for &a in &Approach::ALL {
                agg.entry((fi, a)).or_default().extend(&times[&a]);
            }
            let t = |a: Approach| geomean(&times[&a]);
            let e = |a: Approach| {
                errs[&a].iter().sum::<f64>() / errs[&a].len() as f64
            };
            rep.row(vec![
                d.name.into(),
                format!("{frac:.0e}"),
                fmt_dur(Duration::from_secs_f64(t(Approach::Static))),
                fmt_dur(Duration::from_secs_f64(t(Approach::NaiveDynamic))),
                fmt_dur(Duration::from_secs_f64(t(Approach::DynamicTraversal))),
                fmt_dur(Duration::from_secs_f64(t(Approach::DynamicFrontier))),
                fmt_dur(Duration::from_secs_f64(t(Approach::DynamicFrontierPruning))),
                format!("{:.1e}", e(Approach::DynamicFrontier)),
                format!("{:.1e}", e(Approach::DynamicFrontierPruning)),
                format!("{:.1}x", t(Approach::Static) / t(Approach::DynamicFrontierPruning)),
                format!(
                    "{:.1}x",
                    t(Approach::DynamicTraversal) / t(Approach::DynamicFrontierPruning)
                ),
            ]);
        }
    }
    for (fi, &frac) in fracs.iter().enumerate() {
        let t = |a: Approach| geomean(&agg[&(fi, a)]);
        rep.row(vec![
            "OVERALL".into(),
            format!("{frac:.0e}"),
            fmt_dur(Duration::from_secs_f64(t(Approach::Static))),
            fmt_dur(Duration::from_secs_f64(t(Approach::NaiveDynamic))),
            fmt_dur(Duration::from_secs_f64(t(Approach::DynamicTraversal))),
            fmt_dur(Duration::from_secs_f64(t(Approach::DynamicFrontier))),
            fmt_dur(Duration::from_secs_f64(t(Approach::DynamicFrontierPruning))),
            "".into(),
            "".into(),
            format!("{:.1}x", t(Approach::Static) / t(Approach::DynamicFrontierPruning)),
            format!(
                "{:.1}x",
                t(Approach::DynamicTraversal) / t(Approach::DynamicFrontierPruning)
            ),
        ]);
    }
    rep.emit(&opts.out_dir)
}

// ---------------------------------------------------------------------------
// Figures 9-13: per-batch sequences on each temporal graph
// ---------------------------------------------------------------------------

pub fn exp_fig9_13(runner: &Runner, opts: &ExpOptions, which: Option<&str>) -> Result<()> {
    let ref_cfg = opts.reference_cfg();
    for (i, tg) in temporal_graphs(opts).into_iter().enumerate() {
        if let Some(w) = which {
            if !tg.name.contains(w) {
                continue;
            }
        }
        let exp = format!("fig{}", 9 + i);
        let bsize = ((tg.num_temporal_edges() as f64 * 1e-4).round() as usize).max(1);
        let nb = opts.num_batches().min(if opts.quick { 8 } else { 100 });
        let (base, batches) = tg.replay(bsize, nb);
        let mut rep = Report::new(
            &exp,
            &format!("Per-batch runtime & error on {} (B = 1e-4 |E_T|)", tg.name),
            &["batch", "Static", "ND", "DT", "DF", "DF-P", "err DF-P"],
        );
        // per-batch rows: run all approaches batch by batch
        let out = run_batch_series(
            runner,
            &base,
            &batches,
            &Approach::ALL,
            Substrate::Device,
            &ref_cfg,
        )?;
        let k = out[&Approach::Static].times.len();
        for bi in 0..k {
            rep.row(vec![
                (bi + 1).to_string(),
                fmt_dur(Duration::from_secs_f64(out[&Approach::Static].times[bi])),
                fmt_dur(Duration::from_secs_f64(out[&Approach::NaiveDynamic].times[bi])),
                fmt_dur(Duration::from_secs_f64(out[&Approach::DynamicTraversal].times[bi])),
                fmt_dur(Duration::from_secs_f64(out[&Approach::DynamicFrontier].times[bi])),
                fmt_dur(Duration::from_secs_f64(
                    out[&Approach::DynamicFrontierPruning].times[bi],
                )),
                format!("{:.1e}", out[&Approach::DynamicFrontierPruning].errors[bi]),
            ]);
        }
        rep.emit(&opts.out_dir)?;
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Table 2: DF-P speedup summary (aggregates fig3 + fig4 style runs)
// ---------------------------------------------------------------------------

pub fn exp_table2(runner: &Runner, opts: &ExpOptions) -> Result<()> {
    let ref_cfg = opts.reference_cfg();
    // temporal workload
    let mut temporal_times: HashMap<Approach, Vec<f64>> = HashMap::new();
    for tg in temporal_graphs(opts) {
        let bsize = ((tg.num_temporal_edges() as f64 * 1e-4).round() as usize).max(1);
        let (base, batches) = tg.replay(bsize, opts.num_batches());
        let out = run_batch_series(
            runner, &base, &batches, &Approach::ALL, Substrate::Device, &ref_cfg,
        )?;
        for &a in &Approach::ALL {
            temporal_times.entry(a).or_default().extend(&out[&a].times);
        }
    }
    // random-batch workload (small batches, where the paper reports 3.1x)
    let mut random_times: HashMap<Approach, Vec<f64>> = HashMap::new();
    for d in quick_datasets(opts) {
        let base = d.build();
        let bsize = ((base.num_edges() as f64 * 1e-5).round() as usize).max(1);
        for i in 0..2 {
            let upd = batch::random_batch(&base, bsize, 0.8, d.seed + i);
            let out = run_batch_series(
                runner,
                &base,
                std::slice::from_ref(&upd),
                &Approach::ALL,
                Substrate::Device,
                &ref_cfg,
            )?;
            for &a in &Approach::ALL {
                random_times.entry(a).or_default().extend(&out[&a].times);
            }
        }
    }

    let mut rep = Report::new(
        "table2",
        "Speedup of DF-P vs other approaches (temporal, random-batch)",
        &["vs approach", "temporal", "random", "paper temporal", "paper random"],
    );
    let dfp_t = geomean(&temporal_times[&Approach::DynamicFrontierPruning]);
    let dfp_r = geomean(&random_times[&Approach::DynamicFrontierPruning]);
    let paper = [
        (Approach::Static, "2.1x", "3.1x"),
        (Approach::NaiveDynamic, "1.5x", "1.7x"),
        (Approach::DynamicTraversal, "1.8x", "13.1x"),
        (Approach::DynamicFrontier, "2.1x", "1.3x"),
    ];
    for (a, pt, pr) in paper {
        rep.row(vec![
            a.label().into(),
            format!("{:.1}x", geomean(&temporal_times[&a]) / dfp_t),
            format!("{:.1}x", geomean(&random_times[&a]) / dfp_r),
            pt.into(),
            pr.into(),
        ]);
    }
    rep.emit(&opts.out_dir)
}

// ---------------------------------------------------------------------------
// dispatch
// ---------------------------------------------------------------------------

/// Run an experiment by id (`table1`, `table2`, `fig1` ... `fig13`, `all`).
pub fn run_experiment(id: &str, store: Option<Arc<ArtifactStore>>, opts: &ExpOptions) -> Result<()> {
    let runner = Runner { store, cfg: PagerankConfig::default() };
    match id {
        "table1" | "fig2" | "table1_fig2" => exp_table1_fig2(&runner, opts),
        "table2" => exp_table2(&runner, opts),
        "fig1" => exp_fig1(&runner, opts),
        "fig3" => exp_fig3(&runner, opts, Substrate::Device),
        "fig6" => {
            exp_fig3(&runner, opts, Substrate::Device)?;
            exp_fig3(&runner, opts, Substrate::Native)
        }
        "fig4" | "fig5" | "fig4_5" => exp_fig4_5(&runner, opts, Substrate::Device),
        "fig7" | "fig8" | "fig7_8" => {
            exp_fig4_5(&runner, opts, Substrate::Device)?;
            exp_fig4_5(&runner, opts, Substrate::Native)
        }
        "fig9" | "fig10" | "fig11" | "fig12" | "fig13" => {
            let idx: usize = id[3..].parse().unwrap();
            let names = [
                "sx-mathoverflow",
                "sx-askubuntu",
                "sx-superuser",
                "wiki-talk-temporal",
                "sx-stackoverflow",
            ];
            exp_fig9_13(&runner, opts, Some(names[idx - 9]))
        }
        "fig9_13" => exp_fig9_13(&runner, opts, None),
        "all" => {
            exp_table1_fig2(&runner, opts)?;
            exp_fig1(&runner, opts)?;
            exp_fig3(&runner, opts, Substrate::Device)?;
            exp_fig3(&runner, opts, Substrate::Native)?;
            exp_fig4_5(&runner, opts, Substrate::Device)?;
            exp_fig4_5(&runner, opts, Substrate::Native)?;
            exp_fig9_13(&runner, opts, None)?;
            exp_table2(&runner, opts)
        }
        other => bail!("unknown experiment {other} (try: table1 table2 fig1 fig3 fig4 fig6 fig7 fig9..fig13 all)"),
    }
}
