//! Artifact store: loads HLO-text artifacts, compiles them on the PJRT CPU
//! client (the "GPU" of this testbed), and caches the executables.

use std::collections::HashMap;
use std::sync::Mutex;

use anyhow::{Context, Result};

use super::manifest::{ArtifactSpec, Manifest, TierSpec};

/// Compiled-executable cache over one PJRT client.
pub struct ArtifactStore {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Mutex<HashMap<(String, String), std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl ArtifactStore {
    /// Open the default artifacts directory on a fresh CPU PJRT client.
    pub fn open_default() -> Result<Self> {
        Self::open(&Manifest::default_dir())
    }

    /// Open a specific artifacts directory.
    pub fn open(dir: &std::path::Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Self { client, manifest, cache: Mutex::new(HashMap::new()) })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Smallest tier fitting the graph, if any.
    pub fn tier_for(&self, n: usize, m: usize) -> Option<TierSpec> {
        self.manifest.smallest_fitting_tier(n, m).cloned()
    }

    /// Pack a graph into the smallest tier it actually fits, retrying
    /// larger tiers when the hub-chunk capacity overflows (degenerate
    /// hub-heavy degree distributions).
    pub fn pack_graph(
        &self,
        g: &crate::graph::CsrGraph,
        gt: &crate::graph::CsrGraph,
    ) -> Result<super::DeviceGraph> {
        let n = g.num_vertices();
        let m = g.num_edges();
        let mut tiers: Vec<&TierSpec> =
            self.manifest.tiers.iter().filter(|t| t.fits(n, m)).collect();
        tiers.sort_by_key(|t| t.v);
        let mut last_err = None;
        for tier in tiers {
            match super::DeviceGraph::pack(g, gt, tier) {
                Ok(dg) => return Ok(dg),
                Err(e) => last_err = Some(e),
            }
        }
        Err(last_err
            .unwrap_or_else(|| anyhow::anyhow!("graph (n={n}, m={m}) exceeds largest tier")))
    }

    /// Get (compiling and caching on first use) the executable for
    /// `name @ tier`.
    pub fn executable(
        &self,
        name: &str,
        tier: &str,
    ) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        let key = (name.to_string(), tier.to_string());
        if let Some(exe) = self.cache.lock().unwrap().get(&key) {
            return Ok(exe.clone());
        }
        let spec = self.manifest.artifact(name, tier)?;
        let exe = std::sync::Arc::new(self.compile(spec)?);
        self.cache.lock().unwrap().insert(key, exe.clone());
        Ok(exe)
    }

    fn compile(&self, spec: &ArtifactSpec) -> Result<xla::PjRtLoadedExecutable> {
        let path = self.manifest.artifact_path(spec);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parse {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {}@{}: {e}", spec.name, spec.tier))
    }

    /// Eagerly compile every artifact of a tier (used by the server at
    /// startup so the request path never compiles).
    pub fn warmup(&self, tier: &str) -> Result<usize> {
        let names: Vec<String> = self
            .manifest
            .artifacts
            .iter()
            .filter(|a| a.tier == tier)
            .map(|a| a.name.clone())
            .collect();
        for n in &names {
            self.executable(n, tier)?;
        }
        Ok(names.len())
    }
}

/// Execute an artifact with host literals and fetch every output literal.
/// Artifacts are lowered with `return_tuple=False` (single packed output),
/// but this helper also unpacks tuple roots for robustness. Inputs are
/// borrowed — `Literal::clone` deep-copies, so hot loops pass references.
/// (The production engines use `runtime::exec` with device-resident
/// buffers instead; this path serves tests and one-shot tools.)
pub fn run(
    exe: &xla::PjRtLoadedExecutable,
    inputs: &[&xla::Literal],
) -> Result<Vec<xla::Literal>> {
    let result = exe
        .execute::<&xla::Literal>(inputs)
        .map_err(|e| anyhow::anyhow!("execute: {e}"))?;
    let lit = result[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow::anyhow!("fetch result: {e}"))?;
    match lit.shape() {
        Ok(xla::Shape::Tuple(_)) => lit
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple result: {e}")),
        _ => Ok(vec![lit]),
    }
}

/// f64 vector literal.
pub fn lit_f64(x: &[f64]) -> xla::Literal {
    xla::Literal::vec1(x)
}

/// i32 vector literal.
pub fn lit_i32(x: &[i32]) -> xla::Literal {
    xla::Literal::vec1(x)
}

/// i32 matrix literal (`rows x cols`, row-major input).
pub fn lit_i32_2d(x: &[i32], rows: usize, cols: usize) -> Result<xla::Literal> {
    assert_eq!(x.len(), rows * cols);
    xla::Literal::vec1(x)
        .reshape(&[rows as i64, cols as i64])
        .map_err(|e| anyhow::anyhow!("reshape: {e}"))
}

/// Read an f64 vector back out of a literal.
pub fn to_f64(lit: &xla::Literal) -> Result<Vec<f64>> {
    lit.to_vec::<f64>().map_err(|e| anyhow::anyhow!("to_vec: {e}"))
}
