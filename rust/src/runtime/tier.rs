//! Device graph packing — the Rust mirror of `python/compile/formats.py`.
//!
//! Packs a CSR snapshot into the fixed-shape, sentinel-padded arrays the AOT
//! artifacts consume: the in-/out-side ELL matrices ("thread-per-vertex"
//! partition), hub chunk matrices ("block-per-vertex" partition), the flat
//! edge list (ablation + flat expansion), the inverse out-degree /
//! validity / 1/n vectors, and the vertex→chunk-row maps used to build
//! worklists for the compacted step variants.
//!
//! The packing *is* the paper's Algorithm 4 partitioning step (vertices are
//! routed to the ELL or hub structure by comparing their degree against the
//! manifest's `degree_threshold`), so its runtime is reported as the
//! partitioning component of the measured time.

use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::graph::CsrGraph;

use super::manifest::TierSpec;

/// One direction (in or out) packed as ELL rows + hub chunks.
#[derive(Debug, Clone)]
pub struct PackedSide {
    /// `[V * W]` row-major ELL neighbor ids; hub rows all-sentinel.
    pub ell: Vec<i32>,
    /// `[NC * C]` row-major hub chunk neighbor ids.
    pub hub_edges: Vec<i32>,
    /// `[NC]` destination (in-side) / source (out-side) vertex per chunk row.
    pub hub_seg: Vec<i32>,
    /// Per vertex: (first chunk row, number of chunk rows); (0, 0) for
    /// non-hub vertices. Used for worklist construction.
    pub chunk_rows: Vec<(u32, u32)>,
    /// Number of hub vertices (degree > threshold).
    pub n_hubs: usize,
    /// Number of chunk rows in use.
    pub n_chunk_rows: usize,
}

/// A graph fully packed for one tier.
#[derive(Debug, Clone)]
pub struct DeviceGraph {
    pub tier: TierSpec,
    pub n: usize,
    pub m: usize,
    /// in-side (pull): partitioned by in-degree — feeds rank computation.
    pub in_side: PackedSide,
    /// out-side (push): partitioned by out-degree — feeds scatter expansion.
    pub out_side: PackedSide,
    /// flat edge list (u → v), sentinel padded to ECAP.
    pub te_src: Vec<i32>,
    pub te_dst: Vec<i32>,
    /// `1/outdeg(v)` for real vertices, 0 beyond (and for the sentinel).
    pub outdeg_inv: Vec<f64>,
    /// 1.0 for real vertices.
    pub valid: Vec<f64>,
    /// `[1/n]`.
    pub inv_n: Vec<f64>,
    /// Packing (= partitioning) time, reported per the paper's measurement
    /// protocol (Section 5.1.5 includes partitioning in the runtime).
    pub pack_time: Duration,
}

fn pack_side(adj: &CsrGraph, tier: &TierSpec) -> Result<PackedSide> {
    let sentinel = tier.sentinel();
    let n = adj.num_vertices();
    let mut ell = vec![sentinel; tier.v * tier.w];
    let mut hub_edges = vec![sentinel; tier.nc * tier.c];
    let mut hub_seg = vec![sentinel; tier.nc];
    let mut chunk_rows = vec![(0u32, 0u32); tier.v];
    let mut row = 0usize;
    let mut n_hubs = 0usize;

    for v in 0..n as u32 {
        let nbrs = adj.neighbors(v);
        if nbrs.len() <= tier.w {
            let base = v as usize * tier.w;
            for (i, &u) in nbrs.iter().enumerate() {
                ell[base + i] = u as i32;
            }
        } else {
            n_hubs += 1;
            let first = row;
            for chunk in nbrs.chunks(tier.c) {
                // row NC-1 is reserved as the worklist sentinel target
                if row >= tier.nc - 1 {
                    bail!("hub chunk overflow in tier {}", tier.name);
                }
                let base = row * tier.c;
                for (i, &u) in chunk.iter().enumerate() {
                    hub_edges[base + i] = u as i32;
                }
                hub_seg[row] = v as i32;
                row += 1;
            }
            chunk_rows[v as usize] = (first as u32, (row - first) as u32);
        }
    }
    Ok(PackedSide { ell, hub_edges, hub_seg, chunk_rows, n_hubs, n_chunk_rows: row })
}

impl DeviceGraph {
    /// Pack `g` (out-adjacency CSR; self-loops required) and its transpose
    /// `gt` into `tier`-shaped arrays.
    pub fn pack(g: &CsrGraph, gt: &CsrGraph, tier: &TierSpec) -> Result<Self> {
        let start = Instant::now();
        let n = g.num_vertices();
        let m = g.num_edges();
        if !tier.fits(n, m) {
            bail!("graph (n={n}, m={m}) does not fit tier {}", tier.name);
        }
        if !g.has_no_dead_ends() {
            bail!("graph has dead ends: add self-loops before packing");
        }

        let in_side = pack_side(gt, tier)?;
        let out_side = pack_side(g, tier)?;

        let sentinel = tier.sentinel();
        let mut te_src = vec![sentinel; tier.ecap];
        let mut te_dst = vec![sentinel; tier.ecap];
        for (i, (u, v)) in g.edges().enumerate() {
            te_src[i] = u as i32;
            te_dst[i] = v as i32;
        }

        let mut outdeg_inv = vec![0.0f64; tier.v];
        let mut valid = vec![0.0f64; tier.v];
        for v in 0..n as u32 {
            outdeg_inv[v as usize] = 1.0 / g.degree(v) as f64;
            valid[v as usize] = 1.0;
        }

        Ok(Self {
            tier: tier.clone(),
            n,
            m,
            in_side,
            out_side,
            te_src,
            te_dst,
            outdeg_inv,
            valid,
            inv_n: vec![1.0 / n as f64],
            pack_time: start.elapsed(),
        })
    }

    /// Pad a per-vertex vector to tier shape.
    pub fn pad(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.n);
        let mut out = vec![0.0f64; self.tier.v];
        out[..self.n].copy_from_slice(x);
        out
    }

    /// Build the worklist pair for a compacted step from affected flags
    /// (tier-shaped f64 0/1). Returns `None` when the frontier exceeds the
    /// worklist capacity (caller falls back to the full-shape step).
    ///
    /// `side` selects which chunk-row map to use: the in-side for rank
    /// steps, the out-side for scatter expansion.
    pub fn worklists(&self, flags: &[f64], side: &PackedSide) -> Option<(Vec<i32>, Vec<i32>)> {
        let sentinel = self.tier.sentinel();
        let mut wl = Vec::with_capacity(self.tier.wl_cap);
        let mut wlc = Vec::with_capacity(self.tier.wl_chunk_cap);
        for v in 0..self.n {
            if flags[v] > 0.0 {
                if wl.len() == self.tier.wl_cap {
                    return None;
                }
                wl.push(v as i32);
                let (first, len) = side.chunk_rows[v];
                if len > 0 {
                    if wlc.len() + len as usize > self.tier.wl_chunk_cap {
                        return None;
                    }
                    wlc.extend((first..first + len).map(|r| r as i32));
                }
            }
        }
        wl.resize(self.tier.wl_cap, sentinel);
        wlc.resize(self.tier.wl_chunk_cap, (self.tier.nc - 1) as i32);
        Some((wl, wlc))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generators::er;
    use crate::runtime::manifest::Manifest;

    /// t10 tier spec, or `None` on checkouts without compiled artifacts.
    fn t10() -> Option<TierSpec> {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: no artifacts at {} (run `make artifacts`)", dir.display());
            return None;
        }
        Some(Manifest::load(&dir).unwrap().tier("t10").unwrap().clone())
    }

    #[test]
    fn pack_roundtrip_in_side() {
        let g = er::generate(200, 5.0, 1).to_csr();
        let gt = g.transpose();
        let Some(tier) = t10() else { return };
        let dg = DeviceGraph::pack(&g, &gt, &tier).unwrap();
        let sentinel = tier.sentinel();

        // reconstruct in-neighbors from ELL + hub chunks
        let mut got: Vec<Vec<i32>> = vec![vec![]; 200];
        for v in 0..200usize {
            for i in 0..tier.w {
                let u = dg.in_side.ell[v * tier.w + i];
                if u != sentinel {
                    got[v].push(u);
                }
            }
        }
        for row in 0..tier.nc {
            let v = dg.in_side.hub_seg[row];
            if v == sentinel {
                continue;
            }
            for i in 0..tier.c {
                let u = dg.in_side.hub_edges[row * tier.c + i];
                if u != sentinel {
                    got[v as usize].push(u);
                }
            }
        }
        for v in 0..200u32 {
            let mut want: Vec<i32> = gt.neighbors(v).iter().map(|&u| u as i32).collect();
            want.sort_unstable();
            got[v as usize].sort_unstable();
            assert_eq!(got[v as usize], want, "vertex {v}");
        }
    }

    #[test]
    fn worklist_covers_flags_and_chunks() {
        let g = er::generate(300, 8.0, 2).to_csr();
        let gt = g.transpose();
        let Some(tier) = t10() else { return };
        let dg = DeviceGraph::pack(&g, &gt, &tier).unwrap();
        let mut flags = vec![0.0; tier.v];
        for v in (0..300).step_by(11) {
            flags[v] = 1.0;
        }
        let (wl, wlc) = dg.worklists(&flags, &dg.in_side).unwrap();
        assert_eq!(wl.len(), tier.wl_cap);
        assert_eq!(wlc.len(), tier.wl_chunk_cap);
        let set: std::collections::HashSet<i32> = wl.iter().copied().collect();
        for v in 0..300 {
            if flags[v] > 0.0 {
                assert!(set.contains(&(v as i32)));
                let (first, len) = dg.in_side.chunk_rows[v];
                for r in first..first + len {
                    assert!(wlc.contains(&(r as i32)));
                }
            }
        }
    }

    #[test]
    fn worklist_overflow_returns_none() {
        let g = er::generate(900, 4.0, 3).to_csr();
        let gt = g.transpose();
        let Some(tier) = t10() else { return }; // wl_cap = 64
        let dg = DeviceGraph::pack(&g, &gt, &tier).unwrap();
        let flags = vec![1.0; tier.v];
        assert!(dg.worklists(&flags, &dg.in_side).is_none());
    }

    #[test]
    fn pack_rejects_too_big() {
        let g = er::generate(2000, 4.0, 4).to_csr();
        let gt = g.transpose();
        let Some(tier) = t10() else { return };
        assert!(DeviceGraph::pack(&g, &gt, &tier).is_err());
    }
}
