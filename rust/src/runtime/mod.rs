//! PJRT runtime: manifest parsing, device-graph packing, and the compiled
//! artifact store. This is the only module that touches the `xla` crate;
//! everything above it works with plain Rust types.

pub mod artifacts;
pub mod exec;
pub mod manifest;
pub mod tier;

pub use artifacts::ArtifactStore;
pub use manifest::{Manifest, TierSpec};
pub use tier::DeviceGraph;
