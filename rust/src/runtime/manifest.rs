//! `artifacts/manifest.json` — the contract written by `python/compile/aot.py`.
//!
//! Parsed with the in-tree minimal JSON parser (`util::json`; offline build).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Value};

/// Baked model constants (must match the engine config at run time).
#[derive(Debug, Clone)]
pub struct Constants {
    pub alpha: f64,
    pub tau_frontier: f64,
    pub tau_prune: f64,
    pub degree_threshold: u32,
    pub ell_width: usize,
    pub chunk_width: usize,
}

/// Fixed-shape size class (mirror of `python/compile/formats.py::Tier`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TierSpec {
    pub name: String,
    pub v: usize,
    pub ecap: usize,
    pub w: usize,
    pub c: usize,
    pub nc: usize,
    pub wl_cap: usize,
    pub wl_chunk_cap: usize,
}

impl TierSpec {
    /// Can a graph with `n` vertices and `m` edges be packed into this tier?
    pub fn fits(&self, n: usize, m: usize) -> bool {
        n <= self.v - 1 && m <= self.ecap
    }

    /// Sentinel vertex id (last slot).
    pub fn sentinel(&self) -> i32 {
        (self.v - 1) as i32
    }
}

/// One input of an artifact.
#[derive(Debug, Clone)]
pub struct InputSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One AOT-lowered HLO program.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub tier: String,
    pub file: String,
    pub sha256: String,
    pub inputs: Vec<InputSpec>,
    pub outputs: Vec<String>,
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub format_version: u32,
    pub kernel_impl: String,
    pub constants: Constants,
    pub tiers: Vec<TierSpec>,
    pub artifacts: Vec<ArtifactSpec>,
    pub dir: PathBuf,
}

fn parse_tier(v: &Value) -> Result<TierSpec> {
    Ok(TierSpec {
        name: v.get("name")?.as_str()?.to_string(),
        v: v.get("v")?.as_usize()?,
        ecap: v.get("ecap")?.as_usize()?,
        w: v.get("w")?.as_usize()?,
        c: v.get("c")?.as_usize()?,
        nc: v.get("nc")?.as_usize()?,
        wl_cap: v.get("wl_cap")?.as_usize()?,
        wl_chunk_cap: v.get("wl_chunk_cap")?.as_usize()?,
    })
}

fn parse_artifact(v: &Value) -> Result<ArtifactSpec> {
    let inputs = v
        .get("inputs")?
        .as_arr()?
        .iter()
        .map(|i| {
            Ok(InputSpec {
                name: i.get("name")?.as_str()?.to_string(),
                shape: i
                    .get("shape")?
                    .as_arr()?
                    .iter()
                    .map(|x| x.as_usize())
                    .collect::<Result<_>>()?,
                dtype: i.get("dtype")?.as_str()?.to_string(),
            })
        })
        .collect::<Result<_>>()?;
    let outputs = v
        .get("outputs")?
        .as_arr()?
        .iter()
        .map(|x| Ok(x.as_str()?.to_string()))
        .collect::<Result<_>>()?;
    Ok(ArtifactSpec {
        name: v.get("name")?.as_str()?.to_string(),
        tier: v.get("tier")?.as_str()?.to_string(),
        file: v.get("file")?.as_str()?.to_string(),
        sha256: v.get("sha256")?.as_str()?.to_string(),
        inputs,
        outputs,
    })
}

impl Manifest {
    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let data = std::fs::read_to_string(&path).with_context(|| {
            format!("read {} (run `make artifacts` first)", path.display())
        })?;
        let v = json::parse(&data).context("parse manifest.json")?;
        let format_version = v.get("format_version")?.as_usize()? as u32;
        if format_version != 1 {
            bail!("unsupported manifest format_version {format_version}");
        }
        let c = v.get("constants")?;
        let constants = Constants {
            alpha: c.get("alpha")?.as_f64()?,
            tau_frontier: c.get("tau_frontier")?.as_f64()?,
            tau_prune: c.get("tau_prune")?.as_f64()?,
            degree_threshold: c.get("degree_threshold")?.as_usize()? as u32,
            ell_width: c.get("ell_width")?.as_usize()?,
            chunk_width: c.get("chunk_width")?.as_usize()?,
        };
        let tiers = v
            .get("tiers")?
            .as_arr()?
            .iter()
            .map(parse_tier)
            .collect::<Result<Vec<_>>>()?;
        let artifacts = v
            .get("artifacts")?
            .as_arr()?
            .iter()
            .map(parse_artifact)
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest {
            format_version,
            kernel_impl: v.get("kernel_impl")?.as_str()?.to_string(),
            constants,
            tiers,
            artifacts,
            dir: dir.to_path_buf(),
        })
    }

    /// Default artifacts directory: `$PAGERANK_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("PAGERANK_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    pub fn tier(&self, name: &str) -> Option<&TierSpec> {
        self.tiers.iter().find(|t| t.name == name)
    }

    /// Smallest tier fitting (n, m), if any.
    pub fn smallest_fitting_tier(&self, n: usize, m: usize) -> Option<&TierSpec> {
        self.tiers.iter().filter(|t| t.fits(n, m)).min_by_key(|t| t.v)
    }

    pub fn artifact(&self, name: &str, tier: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.name == name && a.tier == tier)
            .with_context(|| format!("artifact {name}@{tier} not in manifest"))
    }

    pub fn artifact_path(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }

    /// Index of artifacts by (name, tier).
    pub fn by_key(&self) -> HashMap<(String, String), &ArtifactSpec> {
        self.artifacts
            .iter()
            .map(|a| ((a.name.clone(), a.tier.clone()), a))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        let here = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
        here.join("artifacts")
    }

    /// Manifest, or `None` on checkouts without compiled artifacts (the
    /// device path is optional; `make artifacts` produces them).
    fn load_or_skip() -> Option<Manifest> {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: no artifacts at {} (run `make artifacts`)", dir.display());
            return None;
        }
        Some(Manifest::load(&dir).expect("manifest parses"))
    }

    #[test]
    fn loads_real_manifest() {
        let Some(m) = load_or_skip() else { return };
        assert_eq!(m.constants.alpha, 0.85);
        assert_eq!(m.constants.ell_width, 16);
        assert!(m.tier("t10").is_some());
        assert!(m.artifact("step_plain", "t10").is_ok());
        assert!(m.artifact("nonexistent", "t10").is_err());
        assert_eq!(m.kernel_impl, "fused");
    }

    #[test]
    fn tier_fit_logic() {
        let Some(m) = load_or_skip() else { return };
        let t10 = m.tier("t10").unwrap();
        assert!(t10.fits(1023, 1 << 14));
        assert!(!t10.fits(1024, 10)); // sentinel slot reserved
        assert_eq!(m.smallest_fitting_tier(500, 100).unwrap().name, "t10");
        assert_eq!(m.smallest_fitting_tier(5000, 100).unwrap().name, "t13");
        assert!(m.smallest_fitting_tier(1 << 22, 10).is_none());
    }

    #[test]
    fn artifact_files_exist() {
        let Some(m) = load_or_skip() else { return };
        assert!(!m.artifacts.is_empty());
        for a in &m.artifacts {
            let p = m.artifact_path(a);
            assert!(p.exists(), "{} missing", p.display());
            assert_eq!(a.sha256.len(), 64);
        }
    }

    #[test]
    fn input_shapes_match_tier() {
        let Some(m) = load_or_skip() else { return };
        let t = m.tier("t10").unwrap();
        let a = m.artifact("step_plain", "t10").unwrap();
        let by_name: HashMap<&str, &InputSpec> =
            a.inputs.iter().map(|i| (i.name.as_str(), i)).collect();
        // packed state1 layout: [r | linf]
        assert_eq!(by_name["state"].shape, vec![t.v + 1]);
        assert_eq!(by_name["ell_idx"].shape, vec![t.v, t.w]);
        assert_eq!(by_name["hub_edges"].shape, vec![t.nc, t.c]);
        assert_eq!(by_name["state"].dtype, "float64");
        assert_eq!(by_name["ell_idx"].dtype, "int32");
        // df steps carry the 3-segment state
        let a3 = m.artifact("step_dfp", "t10").unwrap();
        assert_eq!(a3.inputs[0].shape, vec![3 * t.v + 1]);
    }
}
