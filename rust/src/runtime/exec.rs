//! Buffer-resident execution helpers: upload once, chain PJRT buffers
//! between launches, read back only what the algorithm needs (the L∞
//! scalar each iteration; the flag segments in worklist mode).

use anyhow::{ensure, Result};

use super::tier::DeviceGraph;
use super::ArtifactStore;

/// Upload an f64 slice as a device buffer.
pub fn buf_f64(store: &ArtifactStore, data: &[f64], dims: &[usize]) -> Result<xla::PjRtBuffer> {
    store
        .client()
        .buffer_from_host_buffer::<f64>(data, dims, None)
        .map_err(|e| anyhow::anyhow!("upload f64: {e}"))
}

/// Upload an i32 slice as a device buffer.
pub fn buf_i32(store: &ArtifactStore, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
    store
        .client()
        .buffer_from_host_buffer::<i32>(data, dims, None)
        .map_err(|e| anyhow::anyhow!("upload i32: {e}"))
}

/// Execute a single-output artifact on device buffers; returns the output
/// buffer (stays on device).
pub fn exec1(
    exe: &xla::PjRtLoadedExecutable,
    args: &[&xla::PjRtBuffer],
) -> Result<xla::PjRtBuffer> {
    let mut out = exe
        .execute_b::<&xla::PjRtBuffer>(args)
        .map_err(|e| anyhow::anyhow!("execute_b: {e}"))?;
    ensure!(!out.is_empty() && !out[0].is_empty(), "no outputs");
    Ok(out.remove(0).remove(0))
}

/// Download a buffer as f64s.
pub fn read_f64(buf: &xla::PjRtBuffer) -> Result<Vec<f64>> {
    let lit = buf
        .to_literal_sync()
        .map_err(|e| anyhow::anyhow!("to_literal: {e}"))?;
    lit.to_vec::<f64>().map_err(|e| anyhow::anyhow!("to_vec: {e}"))
}

/// Download a single-element buffer.
pub fn read_scalar(buf: &xla::PjRtBuffer) -> Result<f64> {
    let v = read_f64(buf)?;
    ensure!(v.len() == 1, "expected scalar, got {} elements", v.len());
    Ok(v[0])
}

/// All static graph-side buffers for one packed graph, uploaded once per run
/// (the paper's excluded host→device transfer).
pub struct GraphBufs {
    pub odi: xla::PjRtBuffer,
    pub valid: xla::PjRtBuffer,
    pub inv_n: xla::PjRtBuffer,
    pub ell: xla::PjRtBuffer,
    pub hub_edges: xla::PjRtBuffer,
    pub hub_seg: xla::PjRtBuffer,
    pub out_ell: xla::PjRtBuffer,
    pub out_hub_edges: xla::PjRtBuffer,
    pub out_hub_seg: xla::PjRtBuffer,
    pub te_src: xla::PjRtBuffer,
    pub te_dst: xla::PjRtBuffer,
}

impl GraphBufs {
    pub fn build(store: &ArtifactStore, dg: &DeviceGraph) -> Result<Self> {
        let t = &dg.tier;
        Ok(Self {
            odi: buf_f64(store, &dg.outdeg_inv, &[t.v])?,
            valid: buf_f64(store, &dg.valid, &[t.v])?,
            inv_n: buf_f64(store, &dg.inv_n, &[1])?,
            ell: buf_i32(store, &dg.in_side.ell, &[t.v, t.w])?,
            hub_edges: buf_i32(store, &dg.in_side.hub_edges, &[t.nc, t.c])?,
            hub_seg: buf_i32(store, &dg.in_side.hub_seg, &[t.nc])?,
            out_ell: buf_i32(store, &dg.out_side.ell, &[t.v, t.w])?,
            out_hub_edges: buf_i32(store, &dg.out_side.hub_edges, &[t.nc, t.c])?,
            out_hub_seg: buf_i32(store, &dg.out_side.hub_seg, &[t.nc])?,
            te_src: buf_i32(store, &dg.te_src, &[t.ecap])?,
            te_dst: buf_i32(store, &dg.te_dst, &[t.ecap])?,
        })
    }
}
