//! Parallel substrate for the native engines (std-only; the offline build
//! has no rayon): blocked `par_for`/`par_reduce` primitives running on a
//! lazily-initialized **persistent work-stealing pool**.
//!
//! ## Determinism
//!
//! The primitives share one design rule: **the work decomposition is a
//! function of the input size only, never of the thread count or the
//! schedule**. Blocks have a fixed size, each block runs exactly once, and
//! per-block partials are written into a *chunk-indexed* buffer and folded
//! in ascending block order after the region. Execution order is thereby
//! separated from reduction order: a block may run on any worker (including
//! stolen mid-region), yet floating-point results are bit-identical at
//! every thread count and under every steal schedule — `threads = 1` runs
//! the same blocked loops inline — and the rank path needs no atomics
//! (matching the paper's atomics-free GPU design).
//!
//! ## The pool
//!
//! Workers are spawned once (first parallel region) and parked on a
//! condvar. A region is submitted as an epoch-stamped job: task indices are
//! dealt into per-lane deques in contiguous runs, the submitting thread
//! takes lane 0, and workers `i` take lane `i + 1` (so a region asking for
//! `t` threads uses exactly `t` lanes, preserving the thread-scaling
//! sweeps). Each lane pops its own deque LIFO and, when empty, steals FIFO
//! from the other lanes in ring order — idle lanes drain the skewed hub and
//! frontier partitions instead of waiting at the barrier. The submitter
//! always participates, so regions complete even with zero workers (1-core
//! hosts) or when every worker is busy with a concurrent submitter's job.
//!
//! A task closure that panics is caught in the worker (the pool survives);
//! the submitter re-raises it as a typed [`PoolPanic`] payload after the
//! region completes, so callers never deadlock on a poisoned region.
//!
//! The pre-pool behavior — scoped threads spawned per region, blocks dealt
//! round-robin — is kept as [`ExecMode::Spawn`], selectable per-thread with
//! [`push_mode`]; `tests/pool_determinism.rs` proves both paths bitwise
//! equal to the sequential loops across engines, generators, and thread
//! counts.

use std::cell::Cell;
use std::collections::VecDeque;
use std::fmt;
use std::marker::PhantomData;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock, PoisonError};
use std::time::Duration;

/// Default vertices-per-block granularity for rank-vector passes.
pub const DEFAULT_BLOCK: usize = 2048;

/// Number of hardware threads available to this process.
pub fn available() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Resolve a configured thread count: `0` means "all available cores",
/// overridable with the `PAGERANK_THREADS` environment variable (used by
/// ci.sh to run the whole suite at a pinned width). An explicit non-zero
/// count always wins over the environment.
pub fn resolve(threads: usize) -> usize {
    if threads != 0 {
        return threads;
    }
    if let Ok(s) = std::env::var("PAGERANK_THREADS") {
        if let Ok(n) = s.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    available()
}

/// How parallel regions execute: on the persistent stealing pool, or with
/// per-region scoped spawning (the pre-pool behavior, kept as the
/// equivalence reference and as an escape hatch). Results are bitwise
/// identical either way.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// Persistent workers + LIFO-local/FIFO-steal deques (default).
    Persistent,
    /// `std::thread::scope` per region, blocks dealt round-robin.
    Spawn,
}

thread_local! {
    static MODE: Cell<ExecMode> = const { Cell::new(ExecMode::Persistent) };
}

/// The execution mode regions submitted from this thread will use.
pub fn current_mode() -> ExecMode {
    MODE.with(Cell::get)
}

/// The mode implied by a config's `pool_persistent` knob.
pub fn mode_for(pool_persistent: bool) -> ExecMode {
    if pool_persistent {
        ExecMode::Persistent
    } else {
        ExecMode::Spawn
    }
}

/// Install `mode` for the current thread until the guard drops (engines
/// scope this over a whole solve so every region inside — steps, graph
/// builds, frontier expansion — runs the configured strategy).
#[must_use = "the mode reverts when the guard drops"]
pub fn push_mode(mode: ExecMode) -> ModeGuard {
    let prev = MODE.with(|m| m.replace(mode));
    ModeGuard { prev }
}

/// Restores the previously installed [`ExecMode`] on drop.
pub struct ModeGuard {
    prev: ExecMode,
}

impl Drop for ModeGuard {
    fn drop(&mut self) {
        MODE.with(|m| m.set(self.prev));
    }
}

/// Typed panic payload re-raised by the submitter when one or more task
/// closures panicked inside a parallel region. The pool itself survives
/// (workers catch the unwind), every non-poisoned block still ran, and the
/// caller's stack unwinds normally — no deadlocked barrier.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PoolPanic {
    /// Number of blocks whose closure panicked.
    pub chunks: usize,
}

impl fmt::Display for PoolPanic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "parallel region poisoned: {} chunk{} panicked",
            self.chunks,
            if self.chunks == 1 { "" } else { "s" }
        )
    }
}

impl std::error::Error for PoolPanic {}

static STRESS_SEED: AtomicU64 = AtomicU64::new(0);
static STRESS_MAX_MICROS: AtomicU64 = AtomicU64::new(0);

/// Test hook: delay every pool task by a seeded pseudo-random duration in
/// `0..=max_micros` µs, skewing lane finish times to force steals.
/// `(0, 0)` clears the hook. Delays cannot change results — that is the
/// property `tests/pool_determinism.rs` stresses.
pub fn set_stress_delay(seed: u64, max_micros: u64) {
    STRESS_SEED.store(seed, Ordering::Relaxed);
    STRESS_MAX_MICROS.store(max_micros, Ordering::Relaxed);
}

fn stress_delay(task: usize) {
    let max = STRESS_MAX_MICROS.load(Ordering::Relaxed);
    if max == 0 {
        return;
    }
    // splitmix64 of (task, seed): deterministic per task, varied per seed
    let mut x = (task as u64)
        .wrapping_add(STRESS_SEED.load(Ordering::Relaxed))
        .wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^= x >> 31;
    std::thread::sleep(Duration::from_micros(x % (max + 1)));
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    // Task panics are caught before any job lock is released poisoned, but
    // recover anyway: a poisoned pool mutex must never wedge the engines.
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Lifetime-erased pointer to a region's task closure, passable to the
/// long-lived workers. Soundness rests on the job protocol: the pointee is
/// only dereferenced between a successful deque pop and the matching
/// `Job::left` decrement, an interval during which the submitting caller —
/// who owns the closure — is still blocked inside [`run_job`].
struct TaskRef {
    data: *const (),
    call: unsafe fn(*const (), usize),
}

unsafe impl Send for TaskRef {}
unsafe impl Sync for TaskRef {}

fn task_ref<F: Fn(usize) + Sync>(f: &F) -> TaskRef {
    unsafe fn call<F: Fn(usize) + Sync>(data: *const (), task: usize) {
        let f = unsafe { &*(data as *const F) };
        f(task);
    }
    TaskRef { data: (f as *const F).cast(), call: call::<F> }
}

type Deque = Mutex<VecDeque<usize>>;

/// One parallel region: per-lane task deques plus the completion barrier.
struct Job {
    /// `width` deques; task indices dealt in contiguous runs. Lane `l` pops
    /// its own deque back (LIFO), steals the others' fronts (FIFO).
    queues: Vec<Deque>,
    /// Tasks not yet finished; the submitter waits on `done` until zero.
    left: Mutex<usize>,
    done: Condvar,
    /// Blocks whose closure panicked (caught in the worker).
    panics: AtomicUsize,
    task: TaskRef,
}

struct PoolState {
    /// Bumped on every publish; parked workers wake when it moves.
    epoch: u64,
    /// The latest published job. A job overwritten here before its workers
    /// picked it up is simply drained by its own submitter.
    job: Option<Arc<Job>>,
}

struct PoolShared {
    state: Mutex<PoolState>,
    work: Condvar,
}

struct Pool {
    shared: Arc<PoolShared>,
    workers: usize,
}

static POOL: OnceLock<Pool> = OnceLock::new();

fn pool() -> &'static Pool {
    POOL.get_or_init(|| {
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState { epoch: 0, job: None }),
            work: Condvar::new(),
        });
        // The submitter is always lane 0, so `cores - 1` workers saturate
        // the machine. Spawn failures are tolerated: regions still complete
        // through the submitter, just with fewer helpers.
        let workers = available().saturating_sub(1);
        let mut spawned = 0;
        for i in 0..workers {
            let s = Arc::clone(&shared);
            let ok = std::thread::Builder::new()
                .name(format!("pagerank-par-{i}"))
                .spawn(move || worker_loop(&s, i))
                .is_ok();
            spawned += usize::from(ok);
        }
        Pool { shared, workers: spawned }
    })
}

/// Number of persistent workers backing the pool (0 on 1-core hosts; the
/// submitting thread always adds one more lane). Forces pool creation.
pub fn pool_workers() -> usize {
    pool().workers
}

fn worker_loop(shared: &PoolShared, index: usize) {
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = lock(&shared.state);
            while st.epoch == seen {
                st = shared
                    .work
                    .wait(st)
                    .unwrap_or_else(PoisonError::into_inner);
            }
            seen = st.epoch;
            st.job.clone()
        };
        // Worker i serves lane i+1; honoring the region's width keeps
        // `threads = t` meaning *t* lanes even when more workers idle.
        if let Some(job) = job {
            if index + 1 < job.queues.len() {
                run_tasks(&job, index + 1);
            }
        }
    }
}

/// Drain tasks as lane `lane`: own deque LIFO, then FIFO-steal from the
/// other lanes in ring order. Returns once no lane has work left.
fn run_tasks(job: &Job, lane: usize) {
    let width = job.queues.len();
    loop {
        let mut task = lock(&job.queues[lane]).pop_back();
        if task.is_none() {
            for k in 1..width {
                task = lock(&job.queues[(lane + k) % width]).pop_front();
                if task.is_some() {
                    break;
                }
            }
        }
        let Some(t) = task else { return };
        stress_delay(t);
        // SAFETY: `left` stays >= 1 until this task is counted below, so
        // the submitter is still parked in `run_job` and the closure it
        // owns is alive. No job lock is held across the call, so a panic
        // here poisons nothing.
        let ok = catch_unwind(AssertUnwindSafe(|| unsafe {
            (job.task.call)(job.task.data, t)
        }))
        .is_ok();
        if !ok {
            job.panics.fetch_add(1, Ordering::Relaxed);
        }
        let mut left = lock(&job.left);
        *left -= 1;
        if *left == 0 {
            job.done.notify_all();
        }
    }
}

/// Submit `ntasks` task indices across `width` lanes and run them to
/// completion, the caller working as lane 0.
fn run_job<F: Fn(usize) + Sync>(
    width: usize,
    ntasks: usize,
    f: &F,
) -> Result<(), PoolPanic> {
    if ntasks == 0 {
        return Ok(());
    }
    // Deal contiguous runs (not round-robin): a lane's LIFO pops then walk
    // cache-adjacent blocks, and steals migrate whole runs of far blocks.
    let base = ntasks / width;
    let extra = ntasks % width;
    let mut queues = Vec::with_capacity(width);
    let mut next = 0usize;
    for lane in 0..width {
        let take = base + usize::from(lane < extra);
        queues.push(Mutex::new((next..next + take).collect::<VecDeque<_>>()));
        next += take;
    }
    let job = Arc::new(Job {
        queues,
        left: Mutex::new(ntasks),
        done: Condvar::new(),
        panics: AtomicUsize::new(0),
        task: task_ref(f),
    });

    let p = pool();
    {
        let mut st = lock(&p.shared.state);
        st.epoch = st.epoch.wrapping_add(1);
        st.job = Some(Arc::clone(&job));
        p.shared.work.notify_all();
    }

    run_tasks(&job, 0);

    {
        let mut left = lock(&job.left);
        while *left > 0 {
            left = job.done.wait(left).unwrap_or_else(PoisonError::into_inner);
        }
    }

    // Unpublish so parked workers stop holding the job alive; a concurrent
    // submitter may already have replaced it — leave theirs untouched.
    {
        let mut st = lock(&p.shared.state);
        if st.job.as_ref().is_some_and(|cur| Arc::ptr_eq(cur, &job)) {
            st.job = None;
        }
    }

    match job.panics.load(Ordering::Relaxed) {
        0 => Ok(()),
        chunks => Err(PoolPanic { chunks }),
    }
}

/// Run `ntasks` independent tasks `f(task_index)` across `width` lanes,
/// each index exactly once, honoring the thread's [`ExecMode`].
fn execute<F: Fn(usize) + Sync>(width: usize, ntasks: usize, f: F) {
    match current_mode() {
        ExecMode::Persistent => {
            if let Err(p) = run_job(width, ntasks, &f) {
                // Propagate like the scoped-spawn path did, but typed.
                std::panic::panic_any(p);
            }
        }
        ExecMode::Spawn => execute_spawn(width, ntasks, &f),
    }
}

/// Legacy executor: scoped threads per region, task `i` on lane
/// `i mod width` (static round-robin, no stealing).
fn execute_spawn<F: Fn(usize) + Sync>(width: usize, ntasks: usize, f: &F) {
    std::thread::scope(|s| {
        for t in 0..width.min(ntasks) {
            s.spawn(move || {
                let mut task = t;
                while task < ntasks {
                    f(task);
                    task += width;
                }
            });
        }
    });
}

/// Shared view of a mutable slice cut into fixed-size blocks, handing block
/// `i` to whichever lane runs task `i`. The executor guarantees each task
/// index runs exactly once, so the aliased `&mut` blocks stay disjoint.
struct SliceParts<'a, T> {
    ptr: *mut T,
    len: usize,
    block: usize,
    _marker: PhantomData<&'a mut [T]>,
}

unsafe impl<T: Send> Send for SliceParts<'_, T> {}
unsafe impl<T: Send> Sync for SliceParts<'_, T> {}

impl<'a, T> SliceParts<'a, T> {
    fn new(data: &'a mut [T], block: usize) -> Self {
        Self { ptr: data.as_mut_ptr(), len: data.len(), block, _marker: PhantomData }
    }

    /// # Safety
    /// Within one region, each `index` must be claimed by at most one
    /// concurrent caller, and `index * block` must be in bounds.
    #[allow(clippy::mut_from_ref)]
    unsafe fn chunk(&self, index: usize) -> &mut [T] {
        let lo = index * self.block;
        debug_assert!(lo < self.len);
        let hi = (lo + self.block).min(self.len);
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(lo), hi - lo) }
    }
}

/// Chunked parallel-for over disjoint mutable blocks of `data`.
///
/// `f(start, block)` receives the absolute index of the block's first
/// element and the mutable block itself. Blocks are `block`-sized (last one
/// ragged) regardless of `threads`.
pub fn par_for<T, F>(threads: usize, block: usize, data: &mut [T], f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(block > 0);
    let threads = threads.max(1);
    if threads == 1 || data.len() <= block {
        for (bi, chunk) in data.chunks_mut(block).enumerate() {
            f(bi * block, chunk);
        }
        return;
    }
    let ntasks = data.len().div_ceil(block);
    let parts = SliceParts::new(data, block);
    execute(threads, ntasks, |task| {
        // SAFETY: the executor hands each task index to exactly one lane.
        let chunk = unsafe { parts.chunk(task) };
        f(task * block, chunk);
    });
}

/// Chunked parallel map-reduce: like [`par_for`], but `f` returns a
/// per-block partial, written into a chunk-indexed slot and folded with
/// `combine` in ascending block order after the region — a fixed-shape
/// reduction, so the result is independent of thread count and schedule
/// (exactly so for `max`; for `+` the partial sums are over fixed blocks,
/// hence also reproducible under stealing).
pub fn par_reduce<T, F>(
    threads: usize,
    block: usize,
    data: &mut [T],
    init: f64,
    combine: fn(f64, f64) -> f64,
    f: F,
) -> f64
where
    T: Send,
    F: Fn(usize, &mut [T]) -> f64 + Sync,
{
    assert!(block > 0);
    let threads = threads.max(1);
    let nblocks = data.len().div_ceil(block);
    let mut partials = vec![init; nblocks];
    if threads == 1 || data.len() <= block {
        for (bi, (chunk, slot)) in
            data.chunks_mut(block).zip(partials.iter_mut()).enumerate()
        {
            *slot = f(bi * block, chunk);
        }
    } else {
        let parts = SliceParts::new(data, block);
        let slots = SliceParts::new(&mut partials, 1);
        execute(threads, nblocks, |task| {
            // SAFETY: task indices are unique per region; data block `task`
            // and partial slot `task` are each touched by one lane only.
            let chunk = unsafe { parts.chunk(task) };
            let slot = unsafe { slots.chunk(task) };
            slot[0] = f(task * block, chunk);
        });
    }
    partials.into_iter().fold(init, combine)
}

/// Three-slice lockstep variant of [`par_reduce`]: the DF/DF-P vertex pass
/// mutates the new rank vector and both flag vectors at the same index, so
/// all three are chunked with identical block boundaries and handed to `f`
/// together.
#[allow(clippy::too_many_arguments)]
pub fn par_for3_reduce<A, B, C, F>(
    threads: usize,
    block: usize,
    a: &mut [A],
    b: &mut [B],
    c: &mut [C],
    init: f64,
    combine: fn(f64, f64) -> f64,
    f: F,
) -> f64
where
    A: Send,
    B: Send,
    C: Send,
    F: Fn(usize, &mut [A], &mut [B], &mut [C]) -> f64 + Sync,
{
    assert!(block > 0);
    assert!(a.len() == b.len() && b.len() == c.len());
    let threads = threads.max(1);
    let nblocks = a.len().div_ceil(block);
    let mut partials = vec![init; nblocks];
    if threads == 1 || a.len() <= block {
        let it = a
            .chunks_mut(block)
            .zip(b.chunks_mut(block))
            .zip(c.chunks_mut(block))
            .zip(partials.iter_mut());
        for (bi, (((ca, cb), cc), slot)) in it.enumerate() {
            *slot = f(bi * block, ca, cb, cc);
        }
    } else {
        let pa = SliceParts::new(a, block);
        let pb = SliceParts::new(b, block);
        let pc = SliceParts::new(c, block);
        let slots = SliceParts::new(&mut partials, 1);
        execute(threads, nblocks, |task| {
            // SAFETY: unique task index ⇒ all four views are disjoint.
            let ca = unsafe { pa.chunk(task) };
            let cb = unsafe { pb.chunk(task) };
            let cc = unsafe { pc.chunk(task) };
            let slot = unsafe { slots.chunk(task) };
            slot[0] = f(task * block, ca, cb, cc);
        });
    }
    partials.into_iter().fold(init, combine)
}

/// Blocked parallel-for over an index range `0..n` (no slice to chunk):
/// `f(start, end)` is called once per fixed-size block. `f` must only touch
/// state that is disjoint per block, idempotent under concurrent marking
/// (the atomic frontier flags), or routed through [`DisjointWriter`].
pub fn par_for_index<F>(threads: usize, block: usize, n: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    assert!(block > 0);
    let threads = threads.max(1);
    if threads == 1 || n <= block {
        let mut start = 0;
        while start < n {
            f(start, (start + block).min(n));
            start += block;
        }
        return;
    }
    let ntasks = n.div_ceil(block);
    execute(threads, ntasks, |task| {
        let start = task * block;
        f(start, (start + block).min(n));
    });
}

/// Shared view of a mutable slice for scattered-but-provably-disjoint
/// parallel writes (counting-sort placement in the CSR builders and the
/// Algorithm 4 placement pass, where every element has a unique precomputed
/// target slot that `chunks_mut` cannot express).
pub struct DisjointWriter<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: PhantomData<&'a mut [T]>,
}

unsafe impl<T: Send> Send for DisjointWriter<'_, T> {}
unsafe impl<T: Send> Sync for DisjointWriter<'_, T> {}

impl<'a, T: Copy> DisjointWriter<'a, T> {
    pub fn new(data: &'a mut [T]) -> Self {
        Self { ptr: data.as_mut_ptr(), len: data.len(), _marker: PhantomData }
    }

    /// Number of slots.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Write `value` into slot `index`.
    ///
    /// # Safety
    /// Callers must guarantee that, within one parallel region, each index
    /// is written by at most one thread and never read concurrently.
    /// `index` must be in bounds (checked only under debug assertions).
    pub unsafe fn write(&self, index: usize, value: T) {
        debug_assert!(index < self.len);
        unsafe { self.ptr.add(index).write(value) };
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_for_writes_every_block() {
        for threads in [1, 2, 3, 8] {
            let mut data = vec![0usize; 10_007];
            par_for(threads, 64, &mut data, |start, chunk| {
                for (i, x) in chunk.iter_mut().enumerate() {
                    *x = start + i;
                }
            });
            assert!(data.iter().enumerate().all(|(i, &x)| x == i), "t={threads}");
        }
    }

    #[test]
    fn par_reduce_is_thread_count_invariant() {
        // pseudo-random values: the fold order must not depend on threads
        let vals: Vec<f64> = (0..50_000u64)
            .map(|i| ((i.wrapping_mul(6364136223846793005).wrapping_add(1)) >> 11) as f64 / 1e18)
            .collect();
        let mut expect = None;
        for threads in [1, 2, 4, 8] {
            let mut data = vals.clone();
            let sum = par_reduce(threads, 128, &mut data, 0.0, |a, b| a + b, |_, chunk| {
                chunk.iter().sum()
            });
            let max = par_reduce(threads, 128, &mut data, 0.0, f64::max, |_, chunk| {
                chunk.iter().copied().fold(0.0, f64::max)
            });
            match expect {
                None => expect = Some((sum, max)),
                Some((s, m)) => {
                    assert_eq!(s.to_bits(), sum.to_bits(), "sum drifted at t={threads}");
                    assert_eq!(m.to_bits(), max.to_bits(), "max drifted at t={threads}");
                }
            }
        }
    }

    #[test]
    fn par_for3_keeps_lockstep_blocks() {
        for threads in [1, 4] {
            let n = 5_000;
            let mut a = vec![0.0f64; n];
            let mut b = vec![0u8; n];
            let mut c = vec![0u8; n];
            let total = par_for3_reduce(
                threads,
                33,
                &mut a,
                &mut b,
                &mut c,
                0.0,
                |x, y| x + y,
                |start, ca, cb, cc| {
                    assert_eq!(ca.len(), cb.len());
                    assert_eq!(cb.len(), cc.len());
                    for i in 0..ca.len() {
                        ca[i] = (start + i) as f64;
                        cb[i] = 1;
                        cc[i] = 2;
                    }
                    ca.len() as f64
                },
            );
            assert_eq!(total, n as f64);
            assert!(a.iter().enumerate().all(|(i, &x)| x == i as f64));
            assert!(b.iter().all(|&x| x == 1) && c.iter().all(|&x| x == 2));
        }
    }

    #[test]
    fn par_for_index_covers_range_once() {
        use std::sync::Mutex;
        for threads in [1, 2, 5] {
            let seen = Mutex::new(vec![0u32; 1_234]);
            par_for_index(threads, 100, 1_234, |start, end| {
                let mut s = seen.lock().unwrap();
                for i in start..end {
                    s[i] += 1;
                }
            });
            assert!(seen.into_inner().unwrap().iter().all(|&x| x == 1));
        }
    }

    #[test]
    fn disjoint_writer_scattered_permutation() {
        let n = 4_096usize;
        let mut out = vec![0u32; n];
        let w = DisjointWriter::new(&mut out);
        // scatter i -> slot (i * 5) % n (5 coprime with 4096: a permutation)
        par_for_index(4, 64, n, |start, end| {
            for i in start..end {
                unsafe { w.write(i * 5 % n, i as u32) };
            }
        });
        let mut seen = vec![false; n];
        for (slot, &v) in out.iter().enumerate() {
            assert_eq!((v as usize * 5) % n, slot);
            assert!(!seen[v as usize]);
            seen[v as usize] = true;
        }
    }

    #[test]
    fn pool_and_spawn_modes_bitwise_equal() {
        let vals: Vec<f64> = (0..30_000u64)
            .map(|i| ((i.wrapping_mul(0x2545F4914F6CDD1D)) >> 12) as f64 / 1e15)
            .collect();
        let run = |mode| {
            let _g = push_mode(mode);
            let mut data = vals.clone();
            par_reduce(7, 256, &mut data, 0.0, |a, b| a + b, |_, c| c.iter().sum())
        };
        let pool = run(ExecMode::Persistent);
        let spawn = run(ExecMode::Spawn);
        assert_eq!(pool.to_bits(), spawn.to_bits());
    }

    #[test]
    fn mode_guard_restores_previous_mode() {
        assert_eq!(current_mode(), ExecMode::Persistent);
        {
            let _a = push_mode(ExecMode::Spawn);
            assert_eq!(current_mode(), ExecMode::Spawn);
            {
                let _b = push_mode(ExecMode::Persistent);
                assert_eq!(current_mode(), ExecMode::Persistent);
            }
            assert_eq!(current_mode(), ExecMode::Spawn);
        }
        assert_eq!(current_mode(), ExecMode::Persistent);
    }

    #[test]
    fn pool_survives_many_regions() {
        // Exercise job handoff/reuse: many small regions back to back must
        // all complete on the same persistent workers.
        let mut data = vec![0u64; 40 * 97];
        for round in 0..200u64 {
            par_for(4, 97, &mut data, |_, chunk| {
                for x in chunk.iter_mut() {
                    *x += round;
                }
            });
        }
        let want: u64 = (0..200).sum();
        assert!(data.iter().all(|&x| x == want));
    }

    #[test]
    fn task_panic_is_typed_and_pool_stays_usable() {
        let caught = catch_unwind(AssertUnwindSafe(|| {
            let mut data = vec![0u8; 6 * 512];
            par_for(3, 512, &mut data, |start, _| {
                if start == 512 {
                    panic!("injected task panic");
                }
            });
        }))
        .unwrap_err();
        let p = caught.downcast_ref::<PoolPanic>().expect("typed PoolPanic payload");
        assert_eq!(p.chunks, 1);
        assert!(p.to_string().contains("1 chunk panicked"));

        // same pool, next region: clean run with correct results
        let mut data = vec![0usize; 6 * 512];
        par_for(3, 512, &mut data, |start, chunk| {
            for (i, x) in chunk.iter_mut().enumerate() {
                *x = start + i;
            }
        });
        assert!(data.iter().enumerate().all(|(i, &x)| x == i));
    }

    #[test]
    fn stress_delays_never_change_results() {
        let vals: Vec<f64> = (0..20_000u64)
            .map(|i| ((i.wrapping_mul(0x9E3779B97F4A7C15)) >> 13) as f64 / 1e14)
            .collect();
        let base = {
            let mut data = vals.clone();
            par_reduce(1, 128, &mut data, 0.0, |a, b| a + b, |_, c| c.iter().sum())
        };
        for seed in [1u64, 42, 2026] {
            set_stress_delay(seed, 40);
            let mut data = vals.clone();
            let got =
                par_reduce(5, 128, &mut data, 0.0, |a, b| a + b, |_, c| c.iter().sum());
            set_stress_delay(0, 0);
            assert_eq!(got.to_bits(), base.to_bits(), "seed={seed}");
        }
    }

    #[test]
    fn resolve_honors_env_and_explicit_counts() {
        assert_eq!(resolve(3), 3, "explicit count wins");
        assert!(resolve(0) >= 1);
        // pool introspection: worker count is cores - 1 (possibly 0)
        assert_eq!(pool_workers(), available().saturating_sub(1));
    }
}
