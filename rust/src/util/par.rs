//! Scoped-thread work pool for the native engines (std-only; offline build
//! has no rayon). The primitives here share one design rule: **the work
//! decomposition is a function of the input size only, never of the thread
//! count**. Blocks have a fixed size, each block's result is computed by
//! exactly one thread, and per-block partials are reduced in ascending
//! block order. Floating-point results are therefore bit-identical at every
//! thread count — `threads = 1` runs the same blocked loops inline — and
//! the rank path needs no atomics (matching the paper's atomics-free GPU
//! design).
//!
//! Threads are spawned per parallel region with [`std::thread::scope`],
//! which lets closures borrow the caller's slices directly. Blocks are
//! dealt to lanes round-robin (block `i` → lane `i mod threads`), a static
//! schedule that keeps the region barrier-light; an amortized persistent
//! pool is a recorded follow-on (ROADMAP "Open items").

use std::marker::PhantomData;

/// Default vertices-per-block granularity for rank-vector passes.
pub const DEFAULT_BLOCK: usize = 2048;

/// Number of hardware threads available to this process.
pub fn available() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Resolve a configured thread count: `0` means "all available cores".
pub fn resolve(threads: usize) -> usize {
    if threads == 0 {
        available()
    } else {
        threads
    }
}

/// Chunked parallel-for over disjoint mutable blocks of `data`.
///
/// `f(start, block)` receives the absolute index of the block's first
/// element and the mutable block itself. Blocks are `block`-sized (last one
/// ragged) regardless of `threads`.
pub fn par_for<T, F>(threads: usize, block: usize, data: &mut [T], f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(block > 0);
    let threads = threads.max(1);
    if threads == 1 || data.len() <= block {
        for (bi, chunk) in data.chunks_mut(block).enumerate() {
            f(bi * block, chunk);
        }
        return;
    }
    std::thread::scope(|s| {
        let f = &f;
        let mut lanes: Vec<Vec<(usize, &mut [T])>> =
            (0..threads).map(|_| Vec::new()).collect();
        for (bi, chunk) in data.chunks_mut(block).enumerate() {
            lanes[bi % threads].push((bi * block, chunk));
        }
        for lane in lanes {
            if lane.is_empty() {
                continue;
            }
            s.spawn(move || {
                for (start, chunk) in lane {
                    f(start, chunk);
                }
            });
        }
    });
}

type ReduceLane<'a, T> = Vec<(usize, &'a mut [T], &'a mut f64)>;

/// Chunked parallel map-reduce: like [`par_for`], but `f` returns a per-block
/// partial and the partials are folded with `combine` in ascending block
/// order — a fixed-shape reduction, so the result is independent of thread
/// count and scheduling (exactly so for `max`; for `+` the partial sums are
/// over fixed blocks, hence also reproducible).
pub fn par_reduce<T, F>(
    threads: usize,
    block: usize,
    data: &mut [T],
    init: f64,
    combine: fn(f64, f64) -> f64,
    f: F,
) -> f64
where
    T: Send,
    F: Fn(usize, &mut [T]) -> f64 + Sync,
{
    assert!(block > 0);
    let threads = threads.max(1);
    let nblocks = data.len().div_ceil(block);
    let mut partials = vec![init; nblocks];
    if threads == 1 || data.len() <= block {
        for (bi, (chunk, slot)) in
            data.chunks_mut(block).zip(partials.iter_mut()).enumerate()
        {
            *slot = f(bi * block, chunk);
        }
    } else {
        std::thread::scope(|s| {
            let f = &f;
            let mut lanes: Vec<ReduceLane<'_, T>> =
                (0..threads).map(|_| Vec::new()).collect();
            for (bi, (chunk, slot)) in
                data.chunks_mut(block).zip(partials.iter_mut()).enumerate()
            {
                lanes[bi % threads].push((bi * block, chunk, slot));
            }
            for lane in lanes {
                if lane.is_empty() {
                    continue;
                }
                s.spawn(move || {
                    for (start, chunk, slot) in lane {
                        *slot = f(start, chunk);
                    }
                });
            }
        });
    }
    partials.into_iter().fold(init, combine)
}

type ReduceLane3<'a, A, B, C> =
    Vec<(usize, &'a mut [A], &'a mut [B], &'a mut [C], &'a mut f64)>;

/// Three-slice lockstep variant of [`par_reduce`]: the DF/DF-P vertex pass
/// mutates the new rank vector and both flag vectors at the same index, so
/// all three are chunked with identical block boundaries and handed to `f`
/// together.
#[allow(clippy::too_many_arguments)]
pub fn par_for3_reduce<A, B, C, F>(
    threads: usize,
    block: usize,
    a: &mut [A],
    b: &mut [B],
    c: &mut [C],
    init: f64,
    combine: fn(f64, f64) -> f64,
    f: F,
) -> f64
where
    A: Send,
    B: Send,
    C: Send,
    F: Fn(usize, &mut [A], &mut [B], &mut [C]) -> f64 + Sync,
{
    assert!(block > 0);
    assert!(a.len() == b.len() && b.len() == c.len());
    let threads = threads.max(1);
    let nblocks = a.len().div_ceil(block);
    let mut partials = vec![init; nblocks];
    if threads == 1 || a.len() <= block {
        let it = a
            .chunks_mut(block)
            .zip(b.chunks_mut(block))
            .zip(c.chunks_mut(block))
            .zip(partials.iter_mut());
        for (bi, (((ca, cb), cc), slot)) in it.enumerate() {
            *slot = f(bi * block, ca, cb, cc);
        }
    } else {
        std::thread::scope(|s| {
            let f = &f;
            let mut lanes: Vec<ReduceLane3<'_, A, B, C>> =
                (0..threads).map(|_| Vec::new()).collect();
            let it = a
                .chunks_mut(block)
                .zip(b.chunks_mut(block))
                .zip(c.chunks_mut(block))
                .zip(partials.iter_mut());
            for (bi, (((ca, cb), cc), slot)) in it.enumerate() {
                lanes[bi % threads].push((bi * block, ca, cb, cc, slot));
            }
            for lane in lanes {
                if lane.is_empty() {
                    continue;
                }
                s.spawn(move || {
                    for (start, ca, cb, cc, slot) in lane {
                        *slot = f(start, ca, cb, cc);
                    }
                });
            }
        });
    }
    partials.into_iter().fold(init, combine)
}

/// Blocked parallel-for over an index range `0..n` (no slice to chunk):
/// `f(start, end)` is called once per fixed-size block, blocks dealt
/// round-robin across the pool. `f` must only touch state that is disjoint
/// per block (or use [`DisjointWriter`]).
pub fn par_for_index<F>(threads: usize, block: usize, n: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    assert!(block > 0);
    let threads = threads.max(1);
    if threads == 1 || n <= block {
        let mut start = 0;
        while start < n {
            f(start, (start + block).min(n));
            start += block;
        }
        return;
    }
    std::thread::scope(|s| {
        let f = &f;
        for t in 0..threads {
            s.spawn(move || {
                let mut bi = t;
                loop {
                    let start = bi * block;
                    if start >= n {
                        break;
                    }
                    f(start, (start + block).min(n));
                    bi += threads;
                }
            });
        }
    });
}

/// Shared view of a mutable slice for scattered-but-provably-disjoint
/// parallel writes (counting-sort placement in the CSR builders and the
/// Algorithm 4 placement pass, where every element has a unique precomputed
/// target slot that `chunks_mut` cannot express).
pub struct DisjointWriter<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: PhantomData<&'a mut [T]>,
}

unsafe impl<T: Send> Send for DisjointWriter<'_, T> {}
unsafe impl<T: Send> Sync for DisjointWriter<'_, T> {}

impl<'a, T: Copy> DisjointWriter<'a, T> {
    pub fn new(data: &'a mut [T]) -> Self {
        Self { ptr: data.as_mut_ptr(), len: data.len(), _marker: PhantomData }
    }

    /// Number of slots.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Write `value` into slot `index`.
    ///
    /// # Safety
    /// Callers must guarantee that, within one parallel region, each index
    /// is written by at most one thread and never read concurrently.
    /// `index` must be in bounds (checked only under debug assertions).
    pub unsafe fn write(&self, index: usize, value: T) {
        debug_assert!(index < self.len);
        unsafe { self.ptr.add(index).write(value) };
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_for_writes_every_block() {
        for threads in [1, 2, 3, 8] {
            let mut data = vec![0usize; 10_007];
            par_for(threads, 64, &mut data, |start, chunk| {
                for (i, x) in chunk.iter_mut().enumerate() {
                    *x = start + i;
                }
            });
            assert!(data.iter().enumerate().all(|(i, &x)| x == i), "t={threads}");
        }
    }

    #[test]
    fn par_reduce_is_thread_count_invariant() {
        // pseudo-random values: the fold order must not depend on threads
        let vals: Vec<f64> = (0..50_000u64)
            .map(|i| ((i.wrapping_mul(6364136223846793005).wrapping_add(1)) >> 11) as f64 / 1e18)
            .collect();
        let mut expect = None;
        for threads in [1, 2, 4, 8] {
            let mut data = vals.clone();
            let sum = par_reduce(threads, 128, &mut data, 0.0, |a, b| a + b, |_, chunk| {
                chunk.iter().sum()
            });
            let max = par_reduce(threads, 128, &mut data, 0.0, f64::max, |_, chunk| {
                chunk.iter().copied().fold(0.0, f64::max)
            });
            match expect {
                None => expect = Some((sum, max)),
                Some((s, m)) => {
                    assert_eq!(s.to_bits(), sum.to_bits(), "sum drifted at t={threads}");
                    assert_eq!(m.to_bits(), max.to_bits(), "max drifted at t={threads}");
                }
            }
        }
    }

    #[test]
    fn par_for3_keeps_lockstep_blocks() {
        for threads in [1, 4] {
            let n = 5_000;
            let mut a = vec![0.0f64; n];
            let mut b = vec![0u8; n];
            let mut c = vec![0u8; n];
            let total = par_for3_reduce(
                threads,
                33,
                &mut a,
                &mut b,
                &mut c,
                0.0,
                |x, y| x + y,
                |start, ca, cb, cc| {
                    assert_eq!(ca.len(), cb.len());
                    assert_eq!(cb.len(), cc.len());
                    for i in 0..ca.len() {
                        ca[i] = (start + i) as f64;
                        cb[i] = 1;
                        cc[i] = 2;
                    }
                    ca.len() as f64
                },
            );
            assert_eq!(total, n as f64);
            assert!(a.iter().enumerate().all(|(i, &x)| x == i as f64));
            assert!(b.iter().all(|&x| x == 1) && c.iter().all(|&x| x == 2));
        }
    }

    #[test]
    fn par_for_index_covers_range_once() {
        use std::sync::Mutex;
        for threads in [1, 2, 5] {
            let seen = Mutex::new(vec![0u32; 1_234]);
            par_for_index(threads, 100, 1_234, |start, end| {
                let mut s = seen.lock().unwrap();
                for i in start..end {
                    s[i] += 1;
                }
            });
            assert!(seen.into_inner().unwrap().iter().all(|&x| x == 1));
        }
    }

    #[test]
    fn disjoint_writer_scattered_permutation() {
        let n = 4_096usize;
        let mut out = vec![0u32; n];
        let w = DisjointWriter::new(&mut out);
        // scatter i -> slot (i * 5) % n (5 coprime with 4096: a permutation)
        par_for_index(4, 64, n, |start, end| {
            for i in start..end {
                unsafe { w.write(i * 5 % n, i as u32) };
            }
        });
        let mut seen = vec![false; n];
        for (slot, &v) in out.iter().enumerate() {
            assert_eq!((v as usize * 5) % n, slot);
            assert!(!seen[v as usize]);
            seen[v as usize] = true;
        }
    }
}
