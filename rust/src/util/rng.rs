//! Deterministic pseudo-random numbers (splitmix64 + xoshiro256**).
//!
//! This environment builds offline, so the `rand` crate is replaced by this
//! substrate. Quality is ample for workload generation (xoshiro256** passes
//! BigCrush); everything is seeded, so datasets and batches are reproducible
//! artifacts.

/// xoshiro256** seeded via splitmix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        // splitmix64 stream to fill the state (never all-zero)
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform usize in [0, n). `n` must be > 0. Uses Lemire's method.
    #[inline]
    pub fn gen_range(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform u64 in [lo, hi).
    #[inline]
    pub fn gen_range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        lo + ((self.next_u64() as u128 * (hi - lo) as u128) >> 64) as u64
    }

    /// Bernoulli(p).
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (floyd's algorithm for small
    /// k, shuffle for large).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        if k * 4 >= n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            return all;
        }
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in n - k..n {
            let t = self.gen_range(j + 1);
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::seed_from_u64(7);
        let mut b = Rng::seed_from_u64(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from_u64(8);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniformity_rough() {
        let mut rng = Rng::seed_from_u64(1);
        let mut buckets = [0usize; 10];
        for _ in 0..100_000 {
            buckets[rng.gen_range(10)] += 1;
        }
        for &b in &buckets {
            assert!((9_000..11_000).contains(&b), "bucket {b}");
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Rng::seed_from_u64(2);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = rng.gen_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::seed_from_u64(3);
        let mut v: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Rng::seed_from_u64(4);
        for (n, k) in [(100, 5), (100, 50), (10, 10), (10, 20)] {
            let s = rng.sample_indices(n, k);
            assert_eq!(s.len(), k.min(n));
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), s.len());
            assert!(s.iter().all(|&i| i < n));
        }
    }
}
