//! Minimal JSON: a recursive-descent parser + a writer, enough for the
//! artifact manifest and the bench-result reports. (Offline build: no
//! serde_json; see Cargo.toml.)

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn get(&self, key: &str) -> Result<&Value> {
        match self {
            Value::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking for {key:?})"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(a) => Ok(a),
            _ => bail!("not an array"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(x) => Ok(*x),
            _ => bail!("not a number"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            bail!("not a non-negative integer: {x}");
        }
        Ok(x as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("not a boolean"),
        }
    }
}

/// Parse a JSON document.
pub fn parse(src: &str) -> Result<Value> {
    let mut p = Parser { b: src.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        bail!("trailing content at byte {}", p.i);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn bump(&mut self) -> Result<u8> {
        let c = self.peek().ok_or_else(|| anyhow!("unexpected end of input"))?;
        self.i += 1;
        Ok(c)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        let got = self.bump()?;
        if got != c {
            bail!("expected {:?} at byte {}, got {:?}", c as char, self.i - 1, got as char);
        }
        Ok(())
    }

    fn literal(&mut self, s: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek().ok_or_else(|| anyhow!("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.literal("true", Value::Bool(true)),
            b'f' => self.literal("false", Value::Bool(false)),
            b'n' => self.literal("null", Value::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Value::Obj(m)),
                c => bail!("expected ',' or '}}', got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Value::Arr(a)),
                c => bail!("expected ',' or ']', got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump()? {
                b'"' => return Ok(out),
                b'\\' => match self.bump()? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump()? as char;
                            code = code * 16
                                + c.to_digit(16)
                                    .ok_or_else(|| anyhow!("bad \\u escape"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    c => bail!("bad escape \\{}", c as char),
                },
                c if c < 0x20 => bail!("control char in string"),
                c => {
                    // re-assemble UTF-8 multibyte sequences byte-wise
                    let start = self.i - 1;
                    let len = match c {
                        0x00..=0x7f => 1,
                        0xc0..=0xdf => 2,
                        0xe0..=0xef => 3,
                        _ => 4,
                    };
                    self.i = start + len;
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| anyhow!("bad utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        while matches!(
            self.peek(),
            Some(b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        ) {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| anyhow!("bad number {s:?} at byte {start}"))
    }
}

/// Escape + quote a string for JSON output.
pub fn quote(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
            "format_version": 1,
            "kernel_impl": "fused",
            "constants": {"alpha": 0.85, "tau_frontier": 1e-06},
            "tiers": [{"name": "t10", "v": 1024}],
            "artifacts": [
                {"name": "step_plain", "inputs": [{"shape": [1024, 16]}]}
            ]
        }"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("format_version").unwrap().as_usize().unwrap(), 1);
        assert_eq!(v.get("kernel_impl").unwrap().as_str().unwrap(), "fused");
        assert_eq!(
            v.get("constants").unwrap().get("alpha").unwrap().as_f64().unwrap(),
            0.85
        );
        assert_eq!(
            v.get("constants").unwrap().get("tau_frontier").unwrap().as_f64().unwrap(),
            1e-6
        );
        let tiers = v.get("tiers").unwrap().as_arr().unwrap();
        assert_eq!(tiers[0].get("v").unwrap().as_usize().unwrap(), 1024);
        let shape = v.get("artifacts").unwrap().as_arr().unwrap()[0]
            .get("inputs")
            .unwrap()
            .as_arr()
            .unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_usize().unwrap())
            .collect::<Vec<_>>();
        assert_eq!(shape, vec![1024, 16]);
    }

    #[test]
    fn strings_and_escapes() {
        let v = parse(r#"{"a": "x\n\"y\" A ü"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_str().unwrap(), "x\n\"y\" A ü");
        assert_eq!(quote("a\"b\nc"), r#""a\"b\nc""#);
        // roundtrip
        let q = quote("weird \\ chars\t");
        let back = parse(&format!("{{\"k\": {q}}}")).unwrap();
        assert_eq!(back.get("k").unwrap().as_str().unwrap(), "weird \\ chars\t");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1, 2,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("12 34").is_err());
    }

    #[test]
    fn numbers() {
        let v = parse("[-1.5e3, 0, 42]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[0].as_f64().unwrap(), -1500.0);
        assert_eq!(a[2].as_usize().unwrap(), 42);
        assert!(a[0].as_usize().is_err());
    }
}
