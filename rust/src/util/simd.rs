//! SIMD-vectorized f64 kernels for the native engines' memory-bound inner
//! loops: contribution scaling (`r[v]/deg(v)` + dangling mass), the pull
//! gather (`Σ contrib[u]` over in-neighbors / hub edge chunks), and the
//! `l1`/`linf` norms. Two backends, runtime-dispatched:
//!
//! * [`Backend::Avx2`] — 256-bit `core::arch::x86_64` intrinsics (4 × f64
//!   lanes, `vgatherdpd` for the index gathers), selected when
//!   `is_x86_feature_detected!("avx2")` holds;
//! * [`Backend::Portable`] — plain 4-lane `[f64; 4]` array loops, always
//!   available, auto-vectorizable, and the escape hatch / differential
//!   reference ([`SimdPolicy::Scalar`], or `PAGERANK_SIMD=0`).
//!
//! ## The fixed lane-tree reduction-order contract
//!
//! Determinism is the hard requirement: ranks must be **bitwise identical**
//! whether a loop ran on the vector unit or the scalar one, at every thread
//! count and in both pool modes (`tests/pool_determinism.rs` pins the full
//! matrix). Both backends therefore implement the *same* fixed-shape
//! reduction — a function of the input length only, never of the backend:
//!
//! 1. **Striping.** Element `i` of a block is folded into lane `i mod 4` of
//!    a 4-lane accumulator; the main loop consumes the `len / 4` full
//!    groups in order, and the `len mod 4` tail elements are folded into
//!    lanes `0..tail` in element order (the vector backends run the tail
//!    with the identical scalar ops).
//! 2. **Horizontal sum.** Lanes combine as `(l0 + l1) + (l2 + l3)` — never
//!    a left-to-right fold.
//! 3. **Horizontal max.** Lanes combine as
//!    `vmax(vmax(l0, l1), vmax(l2, l3))` where `vmax(a, b)` is the x86
//!    `maxpd` rule `if a > b { a } else { b }` (ties and NaNs return `b`),
//!    applied with the accumulator as the first operand.
//! 4. **Elementwise ops** (divide, subtract, abs, zero-blend) are lane-pure
//!    IEEE-754 operations, bit-identical between the scalar and vector
//!    units by the IEEE requirement on basic operations.
//!
//! Because a 4-lane stripe is *not* a left-to-right sum, wiring a loop
//! through this module changes its rounding relative to the old sequential
//! code — by design, once, for both backends. Engine-level goldens compare
//! with tolerances; the bitwise surfaces (thread counts, pool modes, SIMD
//! backends, checkpoint restores) all run through the same stripes.
//!
//! Negative zero: `-0.0` and `0.0` are distinct bit patterns that compare
//! equal; [`util::digest`](crate::util::digest) normalizes the sign bit
//! away before hashing so a semantically-equal `-0.0` can never fail the
//! golden digest.

use std::env;

/// SIMD backend selection knob on [`PagerankConfig`], mirroring the
/// `threads`/`PAGERANK_THREADS` pattern: an explicit setting always wins
/// over the environment.
///
/// [`PagerankConfig`]: crate::engines::config::PagerankConfig
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SimdPolicy {
    /// Honor the `PAGERANK_SIMD` environment pin if set (`0` forces the
    /// portable scalar loops, anything else the vector backend); otherwise
    /// use the detected vector backend. The default.
    #[default]
    Auto,
    /// Force the portable scalar loops — the escape hatch, and the
    /// reference side of every differential test.
    Scalar,
    /// Force the vector backend (falls back to portable loops on hardware
    /// without AVX2; results are bitwise identical either way).
    Vector,
}

impl SimdPolicy {
    /// Serialization name (checkpoints, reports).
    pub fn as_str(&self) -> &'static str {
        match self {
            SimdPolicy::Auto => "auto",
            SimdPolicy::Scalar => "scalar",
            SimdPolicy::Vector => "vector",
        }
    }

    /// Parse a serialization name.
    pub fn parse(s: &str) -> Option<SimdPolicy> {
        match s {
            "auto" => Some(SimdPolicy::Auto),
            "scalar" => Some(SimdPolicy::Scalar),
            "vector" => Some(SimdPolicy::Vector),
            _ => None,
        }
    }
}

/// The concrete instruction path a kernel call executes on. Both variants
/// obey the module-level reduction-order contract, so they are bitwise
/// interchangeable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// 4-lane `[f64; 4]` array loops, plain Rust.
    Portable,
    /// 256-bit AVX2 intrinsics (runtime-detected).
    #[cfg(target_arch = "x86_64")]
    Avx2,
}

/// The widest backend this host supports.
pub fn detect() -> Backend {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            return Backend::Avx2;
        }
    }
    Backend::Portable
}

/// Resolve a configured [`SimdPolicy`] to a concrete [`Backend`]:
/// `Scalar`/`Vector` are explicit and win over the environment; `Auto`
/// consults `PAGERANK_SIMD` (`0` pins the scalar path — used by ci.sh to
/// run the whole suite on each side of the differential) and otherwise
/// detects.
pub fn resolve(policy: SimdPolicy) -> Backend {
    match policy {
        SimdPolicy::Scalar => Backend::Portable,
        SimdPolicy::Vector => detect(),
        SimdPolicy::Auto => match env::var("PAGERANK_SIMD") {
            Ok(s) if s.trim() == "0" => Backend::Portable,
            _ => detect(),
        },
    }
}

/// Contract rule 2: fixed lane tree for sums.
#[inline(always)]
fn hsum(l: [f64; 4]) -> f64 {
    (l[0] + l[1]) + (l[2] + l[3])
}

/// Contract rule 3: the x86 `maxpd` rule — ties and NaNs return `b`. Both
/// backends reduce maxima with this exact operation (accumulator first).
#[inline(always)]
fn vmax(a: f64, b: f64) -> f64 {
    if a > b {
        a
    } else {
        b
    }
}

/// Contract rule 3: fixed lane tree for maxima.
#[inline(always)]
fn hmax(l: [f64; 4]) -> f64 {
    vmax(vmax(l[0], l[1]), vmax(l[2], l[3]))
}

/// One contribution-pass element (shared by the portable loop and the
/// vector backends' tails so the per-element ops are literally the same
/// code): `out = r[u]/deg(u)` with dead ends contributing `0` and their
/// rank mass folded into the dangling accumulator lane. Live vertices add
/// `+0.0` to the lane, matching the vector backends' masked add.
#[inline(always)]
fn contrib_lane(
    starts: &[u64],
    ends: &[u64],
    r: &[f64],
    u: usize,
    slot: &mut f64,
    lane: &mut f64,
) {
    let d = ends[u] - starts[u];
    if d == 0 {
        *slot = 0.0;
        *lane += r[u];
    } else {
        *slot = r[u] / d as f64;
        *lane += 0.0;
    }
}

// ---------------------------------------------------------------------------
// Portable backend: 4-lane array loops. Lane assignment is `i mod 4`, so the
// tail lands in lanes 0..tail exactly as the contract requires.
// ---------------------------------------------------------------------------

fn sum_portable(xs: &[f64]) -> f64 {
    let mut l = [0.0f64; 4];
    for (i, &x) in xs.iter().enumerate() {
        l[i % 4] += x;
    }
    hsum(l)
}

fn gather_sum_portable(values: &[f64], idx: &[u32]) -> f64 {
    let mut l = [0.0f64; 4];
    for (i, &j) in idx.iter().enumerate() {
        l[i % 4] += values[j as usize];
    }
    hsum(l)
}

fn gather_div_sum_portable(num: &[f64], den: &[f64], idx: &[u32]) -> f64 {
    let mut l = [0.0f64; 4];
    for (i, &j) in idx.iter().enumerate() {
        l[i % 4] += num[j as usize] / den[j as usize];
    }
    hsum(l)
}

fn contrib_block_portable(
    starts: &[u64],
    ends: &[u64],
    r: &[f64],
    start: usize,
    out: &mut [f64],
) -> f64 {
    let mut l = [0.0f64; 4];
    for (i, slot) in out.iter_mut().enumerate() {
        contrib_lane(starts, ends, r, start + i, slot, &mut l[i % 4]);
    }
    hsum(l)
}

fn l1_portable(a: &[f64], b: &[f64]) -> f64 {
    let mut l = [0.0f64; 4];
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        l[i % 4] += (x - y).abs();
    }
    hsum(l)
}

fn linf_portable(a: &[f64], b: &[f64]) -> f64 {
    let mut l = [0.0f64; 4];
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let lane = &mut l[i % 4];
        *lane = vmax(*lane, (x - y).abs());
    }
    hmax(l)
}

// ---------------------------------------------------------------------------
// AVX2 backend. Every kernel runs the same stripes as the portable loops:
// the vector main loop covers the full 4-groups, the tail reuses the scalar
// per-element ops on the spilled accumulator lanes.
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::{contrib_lane, hmax, hsum};
    use core::arch::x86_64::*;

    #[target_feature(enable = "avx2")]
    pub unsafe fn sum(xs: &[f64]) -> f64 {
        let mut acc = _mm256_setzero_pd();
        let mut chunks = xs.chunks_exact(4);
        for c in &mut chunks {
            acc = _mm256_add_pd(acc, unsafe { _mm256_loadu_pd(c.as_ptr()) });
        }
        let mut l = [0.0f64; 4];
        unsafe { _mm256_storeu_pd(l.as_mut_ptr(), acc) };
        for (j, &x) in chunks.remainder().iter().enumerate() {
            l[j] += x;
        }
        hsum(l)
    }

    /// # Safety
    /// Caller guarantees AVX2, every index in bounds for `values`, and
    /// `values.len() <= i32::MAX` (`vgatherdpd` sign-extends its indices).
    #[target_feature(enable = "avx2")]
    pub unsafe fn gather_sum(values: &[f64], idx: &[u32]) -> f64 {
        let mut acc = _mm256_setzero_pd();
        let mut chunks = idx.chunks_exact(4);
        for c in &mut chunks {
            let vi = unsafe { _mm_loadu_si128(c.as_ptr() as *const __m128i) };
            let g = unsafe { _mm256_i32gather_pd::<8>(values.as_ptr(), vi) };
            acc = _mm256_add_pd(acc, g);
        }
        let mut l = [0.0f64; 4];
        unsafe { _mm256_storeu_pd(l.as_mut_ptr(), acc) };
        for (j, &i) in chunks.remainder().iter().enumerate() {
            l[j] += values[i as usize];
        }
        hsum(l)
    }

    /// # Safety
    /// As [`gather_sum`], for both `num` and `den`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn gather_div_sum(num: &[f64], den: &[f64], idx: &[u32]) -> f64 {
        let mut acc = _mm256_setzero_pd();
        let mut chunks = idx.chunks_exact(4);
        for c in &mut chunks {
            let vi = unsafe { _mm_loadu_si128(c.as_ptr() as *const __m128i) };
            let n = unsafe { _mm256_i32gather_pd::<8>(num.as_ptr(), vi) };
            let d = unsafe { _mm256_i32gather_pd::<8>(den.as_ptr(), vi) };
            acc = _mm256_add_pd(acc, _mm256_div_pd(n, d));
        }
        let mut l = [0.0f64; 4];
        unsafe { _mm256_storeu_pd(l.as_mut_ptr(), acc) };
        for (j, &i) in chunks.remainder().iter().enumerate() {
            l[j] += num[i as usize] / den[i as usize];
        }
        hsum(l)
    }

    /// # Safety
    /// Caller guarantees AVX2, `starts[start + i]` / `ends[start + i]` in
    /// bounds for every `i < out.len()`, and `r[start + i]` in bounds
    /// likewise. For a packed CSR, pass `(&offsets[..n], &offsets[1..])` —
    /// the loads below are then byte-for-byte the old offset-pair loads.
    #[target_feature(enable = "avx2")]
    pub unsafe fn contrib_block(
        starts: &[u64],
        ends: &[u64],
        r: &[f64],
        start: usize,
        out: &mut [f64],
    ) -> f64 {
        // u64 degree -> f64 via the 2^52 magic-bias trick (exact for
        // degrees < 2^52, a given for vertex in-degrees).
        let magic_i = _mm256_set1_epi64x(0x4330_0000_0000_0000);
        let magic_f = _mm256_set1_pd(4_503_599_627_370_496.0); // 2^52
        let zero = _mm256_setzero_si256();
        let mut acc = _mm256_setzero_pd();
        let full = out.len() / 4 * 4;
        let mut i = 0;
        while i < full {
            let u = start + i;
            let lo = unsafe { _mm256_loadu_si256(starts.as_ptr().add(u) as *const __m256i) };
            let hi = unsafe { _mm256_loadu_si256(ends.as_ptr().add(u) as *const __m256i) };
            let deg = _mm256_sub_epi64(hi, lo);
            // all-ones lanes where deg == 0 (dead end)
            let dead = _mm256_castsi256_pd(_mm256_cmpeq_epi64(deg, zero));
            let degf =
                _mm256_sub_pd(_mm256_castsi256_pd(_mm256_or_si256(deg, magic_i)), magic_f);
            let rv = unsafe { _mm256_loadu_pd(r.as_ptr().add(u)) };
            // dead lanes: r/0.0 is ±inf/NaN but blended to +0.0 before the
            // store; live lanes add +0.0 to the dangling accumulator —
            // both exactly matching `contrib_lane`.
            let q = _mm256_div_pd(rv, degf);
            unsafe {
                _mm256_storeu_pd(out.as_mut_ptr().add(i), _mm256_andnot_pd(dead, q))
            };
            acc = _mm256_add_pd(acc, _mm256_and_pd(dead, rv));
            i += 4;
        }
        let mut l = [0.0f64; 4];
        unsafe { _mm256_storeu_pd(l.as_mut_ptr(), acc) };
        for (j, slot) in out[full..].iter_mut().enumerate() {
            contrib_lane(starts, ends, r, start + full + j, slot, &mut l[j]);
        }
        hsum(l)
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn l1(a: &[f64], b: &[f64]) -> f64 {
        let sign = _mm256_set1_pd(-0.0);
        let mut acc = _mm256_setzero_pd();
        let mut ca = a.chunks_exact(4);
        let mut cb = b.chunks_exact(4);
        for (xa, xb) in (&mut ca).zip(&mut cb) {
            let d = _mm256_sub_pd(unsafe { _mm256_loadu_pd(xa.as_ptr()) }, unsafe {
                _mm256_loadu_pd(xb.as_ptr())
            });
            acc = _mm256_add_pd(acc, _mm256_andnot_pd(sign, d));
        }
        let mut l = [0.0f64; 4];
        unsafe { _mm256_storeu_pd(l.as_mut_ptr(), acc) };
        for (j, (&x, &y)) in ca.remainder().iter().zip(cb.remainder()).enumerate() {
            l[j] += (x - y).abs();
        }
        hsum(l)
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn linf(a: &[f64], b: &[f64]) -> f64 {
        let sign = _mm256_set1_pd(-0.0);
        let mut acc = _mm256_setzero_pd();
        let mut ca = a.chunks_exact(4);
        let mut cb = b.chunks_exact(4);
        for (xa, xb) in (&mut ca).zip(&mut cb) {
            let d = _mm256_sub_pd(unsafe { _mm256_loadu_pd(xa.as_ptr()) }, unsafe {
                _mm256_loadu_pd(xb.as_ptr())
            });
            // maxpd(acc, v): acc > v ? acc : v — the `vmax` rule with the
            // accumulator first, as the portable loop does.
            acc = _mm256_max_pd(acc, _mm256_andnot_pd(sign, d));
        }
        let mut l = [0.0f64; 4];
        unsafe { _mm256_storeu_pd(l.as_mut_ptr(), acc) };
        for (j, (&x, &y)) in ca.remainder().iter().zip(cb.remainder()).enumerate() {
            let lane = &mut l[j];
            *lane = super::vmax(*lane, (x - y).abs());
        }
        hmax(l)
    }
}

// ---------------------------------------------------------------------------
// Dispatch. The AVX2 gathers interpret indices as signed 32-bit, so slices
// at or beyond i32::MAX elements fall back to the portable loops (bitwise
// identical by the contract, so the fallback is invisible to callers).
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
const GATHER_MAX: usize = i32::MAX as usize;

/// Striped block sum of `xs` under the lane-tree contract.
pub fn sum(be: Backend, xs: &[f64]) -> f64 {
    match be {
        Backend::Portable => sum_portable(xs),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Backend::Avx2 is only handed out by `detect()`.
        Backend::Avx2 => unsafe { avx2::sum(xs) },
    }
}

/// Striped gather sum `Σ values[idx[i]]` — the pull kernel's inner loop.
/// Every index must be in bounds (the CSR neighbor invariant).
pub fn gather_sum(be: Backend, values: &[f64], idx: &[u32]) -> f64 {
    match be {
        Backend::Portable => gather_sum_portable(values, idx),
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => {
            if values.len() > GATHER_MAX {
                return gather_sum_portable(values, idx);
            }
            // SAFETY: AVX2 detected; indices are in-bounds vertex ids and
            // the base length fits the signed-index gather.
            unsafe { avx2::gather_sum(values, idx) }
        }
    }
}

/// Striped gather-divide sum `Σ num[idx[i]] / den[idx[i]]` — the
/// asynchronous engines' fused contribution pull (`r[u]/deg(u)` without a
/// materialized contrib vector). `num` and `den` must have equal length and
/// every index must be in bounds for both.
pub fn gather_div_sum(be: Backend, num: &[f64], den: &[f64], idx: &[u32]) -> f64 {
    debug_assert_eq!(num.len(), den.len());
    match be {
        Backend::Portable => gather_div_sum_portable(num, den, idx),
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => {
            if num.len() > GATHER_MAX {
                return gather_div_sum_portable(num, den, idx);
            }
            // SAFETY: as `gather_sum`, for both base slices.
            unsafe { avx2::gather_div_sum(num, den, idx) }
        }
    }
}

/// Contribution pass over one vertex block: `out[i] = r[start+i]/deg` with
/// dead ends writing `0.0`, returning the block's dangling rank mass as a
/// striped lane-tree sum. `starts`/`ends` are the per-vertex out-row bounds
/// (`CsrGraph::row_bounds`, both length `n`; a packed CSR passes
/// `(&offsets[..n], &offsets[1..])` so the vector loads are unchanged);
/// `r` the full rank vector; `out` the block
/// `contrib[start..start + out.len()]`.
pub fn contrib_block(
    be: Backend,
    starts: &[u64],
    ends: &[u64],
    r: &[f64],
    start: usize,
    out: &mut [f64],
) -> f64 {
    debug_assert_eq!(starts.len(), ends.len());
    debug_assert!(start + out.len() <= starts.len());
    debug_assert!(start + out.len() <= r.len());
    match be {
        Backend::Portable => contrib_block_portable(starts, ends, r, start, out),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: AVX2 detected; the debug-asserted bounds are the CSR
        // block invariant the parallel substrate already guarantees.
        Backend::Avx2 => unsafe { avx2::contrib_block(starts, ends, r, start, out) },
    }
}

/// Striped L1 distance `Σ |a[i] - b[i]|`. Slices must have equal length.
/// `-0.0` and `0.0` compare equal: their difference is `±0.0` and `abs`
/// folds it to `+0.0`, so a sign-only mismatch contributes exactly zero on
/// both backends.
pub fn l1(be: Backend, a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    match be {
        Backend::Portable => l1_portable(a, b),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Backend::Avx2 is only handed out by `detect()`.
        Backend::Avx2 => unsafe { avx2::l1(a, b) },
    }
}

/// Striped L∞ distance `max |a[i] - b[i]|` under the `vmax` lane tree.
/// NaN differences propagate (unlike the old `f64::max` fold, which
/// silently dropped them) — poisoned inputs now surface as a NaN norm.
pub fn linf(be: Backend, a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    match be {
        Backend::Portable => linf_portable(a, b),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Backend::Avx2 is only handed out by `detect()`.
        Backend::Avx2 => unsafe { avx2::linf(a, b) },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Both backends when the host has a vector unit, otherwise portable
    /// twice (the differential is then trivially green, but every kernel
    /// still runs).
    fn backends() -> Vec<Backend> {
        let mut v = vec![Backend::Portable];
        if detect() != Backend::Portable {
            v.push(detect());
        }
        v
    }

    fn random_values(rng: &mut Rng, len: usize) -> Vec<f64> {
        (0..len)
            .map(|_| match rng.gen_range(16) {
                // mix signs, magnitudes, exact zeros and negative zeros
                0 => 0.0,
                1 => -0.0,
                2 => rng.gen_f64() * 1e300,
                3 => -rng.gen_f64() * 1e-300,
                _ => rng.gen_f64() - 0.5,
            })
            .collect()
    }

    #[test]
    fn lane_tree_shape_is_fixed() {
        // 5 elements: lanes are [a+e, b, c, d]; tree = ((a+e)+b) + (c+d)
        let xs = [1e100, 1.0, -1e100, 2.0, 3.0];
        let want = ((1e100 + 3.0) + 1.0) + (-1e100 + 2.0);
        assert_eq!(sum(Backend::Portable, &xs).to_bits(), want.to_bits());
    }

    #[test]
    fn backends_bitwise_equal_on_sums_and_norms() {
        let mut rng = Rng::seed_from_u64(11);
        for len in [0usize, 1, 2, 3, 4, 5, 7, 8, 15, 64, 67, 1023] {
            let a = random_values(&mut rng, len);
            let b = random_values(&mut rng, len);
            let base_sum = sum(Backend::Portable, &a);
            let base_l1 = l1(Backend::Portable, &a, &b);
            let base_linf = linf(Backend::Portable, &a, &b);
            for be in backends() {
                assert_eq!(sum(be, &a).to_bits(), base_sum.to_bits(), "sum len={len}");
                assert_eq!(l1(be, &a, &b).to_bits(), base_l1.to_bits(), "l1 len={len}");
                assert_eq!(
                    linf(be, &a, &b).to_bits(),
                    base_linf.to_bits(),
                    "linf len={len}"
                );
            }
        }
    }

    #[test]
    fn backends_bitwise_equal_on_gathers() {
        let mut rng = Rng::seed_from_u64(23);
        let values = random_values(&mut rng, 997);
        let dens: Vec<f64> = (0..997).map(|_| 1.0 + rng.gen_range(40) as f64).collect();
        for len in [0usize, 1, 3, 4, 6, 9, 31, 256, 1000] {
            let idx: Vec<u32> = (0..len).map(|_| rng.gen_range(997) as u32).collect();
            let base = gather_sum(Backend::Portable, &values, &idx);
            let base_div = gather_div_sum(Backend::Portable, &values, &dens, &idx);
            for be in backends() {
                assert_eq!(
                    gather_sum(be, &values, &idx).to_bits(),
                    base.to_bits(),
                    "gather len={len}"
                );
                assert_eq!(
                    gather_div_sum(be, &values, &dens, &idx).to_bits(),
                    base_div.to_bits(),
                    "gather_div len={len}"
                );
            }
        }
    }

    #[test]
    fn backends_bitwise_equal_on_contrib_blocks() {
        let mut rng = Rng::seed_from_u64(37);
        // offsets with dead ends sprinkled in (equal consecutive offsets)
        let n = 530usize;
        let mut offsets = Vec::with_capacity(n + 1);
        let mut acc = 0u64;
        offsets.push(0);
        for _ in 0..n {
            if rng.gen_bool(0.15) {
                // dead end: degree 0
            } else {
                acc += 1 + rng.gen_range(2000) as u64;
            }
            offsets.push(acc);
        }
        let r = random_values(&mut rng, n);
        let (starts, ends) = (&offsets[..n], &offsets[1..]);
        for (start, len) in [(0usize, 4usize), (0, 530), (3, 7), (128, 257), (520, 10)] {
            let mut base_out = vec![0.0f64; len];
            let base =
                contrib_block(Backend::Portable, starts, ends, &r, start, &mut base_out);
            for be in backends() {
                let mut out = vec![99.0f64; len];
                let dangling = contrib_block(be, starts, ends, &r, start, &mut out);
                assert_eq!(dangling.to_bits(), base.to_bits(), "dangling {start}+{len}");
                for (i, (x, y)) in out.iter().zip(&base_out).enumerate() {
                    assert_eq!(x.to_bits(), y.to_bits(), "contrib[{}]", start + i);
                }
            }
        }
    }

    #[test]
    fn contrib_block_handles_dead_ends() {
        // vertex 1 is a dead end: contrib 0, mass in the dangling sum
        let offsets = [0u64, 2, 2, 5];
        let r = [0.5, 0.25, 0.25];
        for be in backends() {
            let mut out = [9.0f64; 3];
            let dangling = contrib_block(be, &offsets[..3], &offsets[1..], &r, 0, &mut out);
            assert_eq!(out[0].to_bits(), (0.5 / 2.0).to_bits());
            assert_eq!(out[1].to_bits(), 0.0f64.to_bits(), "dead end writes +0.0");
            assert_eq!(out[2].to_bits(), (0.25 / 3.0).to_bits());
            assert_eq!(dangling.to_bits(), 0.25f64.to_bits());
        }
    }

    #[test]
    fn norms_treat_negative_zero_as_equal() {
        let a = [0.0, -0.0, 1.0];
        let b = [-0.0, 0.0, 1.0];
        for be in backends() {
            assert_eq!(l1(be, &a, &b).to_bits(), 0.0f64.to_bits());
            assert_eq!(linf(be, &a, &b).to_bits(), 0.0f64.to_bits());
        }
    }

    #[test]
    fn policy_resolution_explicit_wins() {
        assert_eq!(resolve(SimdPolicy::Scalar), Backend::Portable);
        // Vector resolves to whatever the host supports…
        assert_eq!(resolve(SimdPolicy::Vector), detect());
        // …and parsing round-trips
        for p in [SimdPolicy::Auto, SimdPolicy::Scalar, SimdPolicy::Vector] {
            assert_eq!(SimdPolicy::parse(p.as_str()), Some(p));
        }
        assert_eq!(SimdPolicy::parse("avx512"), None);
        assert_eq!(SimdPolicy::default(), SimdPolicy::Auto);
    }

    #[test]
    fn vmax_follows_maxpd_rule() {
        assert_eq!(vmax(1.0, 2.0), 2.0);
        assert_eq!(vmax(2.0, 1.0), 2.0);
        // ties return the second operand (bit check distinguishes ±0.0)
        assert_eq!(vmax(0.0, -0.0).to_bits(), (-0.0f64).to_bits());
        assert_eq!(vmax(-0.0, 0.0).to_bits(), 0.0f64.to_bits());
        // NaN in either operand returns the second operand
        assert!(vmax(f64::NAN, 1.0) == 1.0);
        assert!(vmax(1.0, f64::NAN).is_nan());
    }
}
