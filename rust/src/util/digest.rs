//! Bitwise rank digests for the determinism gates.
//!
//! `ci.sh` and `tests/pool_determinism.rs` compare rank vectors by hashing
//! their raw f64 bits with FNV-1a: any schedule-, thread-count-, pool-mode-
//! or SIMD-backend-dependent bit anywhere in the stack changes the digest.
//! The one bit pattern that must *not* fail the gate is the sign of zero:
//! `-0.0 == 0.0` semantically, and a backend is allowed to produce either
//! (e.g. a vector blend writing `+0.0` where a scalar multiply produced
//! `-0.0`), so [`fnv1a_ranks`] normalizes negative zero to `+0.0` before
//! hashing.

/// Fold `-0.0` to `+0.0`; every other value (including NaN) is unchanged.
#[inline]
pub fn normalize_zero(x: f64) -> f64 {
    if x == 0.0 {
        0.0
    } else {
        x
    }
}

/// 64-bit FNV-1a over the little-endian bits of `ranks`, with negative
/// zeros normalized away (see module doc).
pub fn fnv1a_ranks(ranks: &[f64]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &r in ranks {
        for b in normalize_zero(r).to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalize_zero_folds_sign_only() {
        assert_eq!(normalize_zero(-0.0).to_bits(), 0.0f64.to_bits());
        assert_eq!(normalize_zero(0.0).to_bits(), 0.0f64.to_bits());
        assert_eq!(normalize_zero(1.5), 1.5);
        assert_eq!(normalize_zero(-1.5), -1.5);
        assert!(normalize_zero(f64::NAN).is_nan());
        assert_eq!(normalize_zero(f64::NEG_INFINITY), f64::NEG_INFINITY);
    }

    #[test]
    fn digest_ignores_zero_sign_but_nothing_else() {
        let a = [0.25, 0.0, 0.5];
        let b = [0.25, -0.0, 0.5];
        assert_eq!(fnv1a_ranks(&a), fnv1a_ranks(&b), "-0.0 vs 0.0 must agree");
        let c = [0.25, 0.0, 0.5 + f64::EPSILON];
        assert_ne!(fnv1a_ranks(&a), fnv1a_ranks(&c), "one ulp must differ");
        assert_ne!(fnv1a_ranks(&a), fnv1a_ranks(&a[..2]), "length matters");
    }

    #[test]
    fn digest_matches_known_fnv1a_vector() {
        // FNV-1a of 8 zero bytes (one 0.0 rank) — the offset basis folded
        // through eight zero bytes.
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for _ in 0..8 {
            h = (h ^ 0).wrapping_mul(0x0000_0100_0000_01b3);
        }
        assert_eq!(fnv1a_ranks(&[0.0]), h);
        assert_eq!(fnv1a_ranks(&[-0.0]), h);
    }
}
