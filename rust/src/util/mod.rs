//! Self-contained substrates for the offline build: deterministic RNG and
//! minimal JSON (replacing the `rand` / `serde_json` crates).

pub mod json;
pub mod rng;

pub use rng::Rng;
