//! Self-contained substrates for the offline build: deterministic RNG,
//! minimal JSON (replacing the `rand` / `serde_json` crates), and the
//! scoped-thread work pool the native engines run on (replacing `rayon`).

pub mod json;
pub mod par;
pub mod rng;

pub use rng::Rng;
