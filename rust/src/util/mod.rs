//! Self-contained substrates for the offline build: deterministic RNG,
//! minimal JSON (replacing the `rand` / `serde_json` crates), the
//! work-stealing pool the native engines run on (replacing `rayon`), the
//! runtime-dispatched SIMD kernels for their inner loops, and the bitwise
//! rank digest used by the determinism gates.

pub mod digest;
pub mod json;
pub mod par;
pub mod rng;
pub mod simd;

pub use rng::Rng;
pub use simd::SimdPolicy;
