//! # pagerank-dynamic
//!
//! Reproduction of *"Efficient GPU Implementation of Static and Incrementally
//! Expanding DF-P PageRank for Dynamic Graphs"* (Sahu, 2024) as a three-layer
//! Rust + JAX/Pallas stack:
//!
//! - **L3 (this crate)**: the dynamic-graph coordinator — graph substrates,
//!   batch-update pipeline, the five PageRank approaches (Static,
//!   Naive-dynamic, Dynamic Traversal, Dynamic Frontier, DF with Pruning),
//!   CPU baselines, and the benchmark harness reproducing every table and
//!   figure of the paper.
//! - **L2/L1 (build time, `python/`)**: one PageRank iteration and frontier
//!   expansion lowered ahead-of-time to HLO artifacts; the Pallas kernels
//!   implement the paper's thread-per-vertex / block-per-vertex split.
//! - **runtime**: [`runtime`] loads the artifacts on the PJRT CPU client
//!   (the "simulated GPU") and [`engines::device`] drives them.
//!
//! See `DESIGN.md` for the architecture and `EXPERIMENTS.md` for
//! paper-vs-measured results.

pub mod batch;
pub mod coordinator;
pub mod costmodel;
pub mod engines;
pub mod generators;
pub mod graph;
pub mod harness;
pub mod runtime;
pub mod temporal;
pub mod util;

pub use engines::config::PagerankConfig;
pub use graph::csr::CsrGraph;
