//! Temporal graphs: timestamped edge streams and the paper's replay
//! protocol (Section 5.1.4) — load the first 90% of temporal edges, add
//! self-loops, then feed the remaining edges in 100 consecutive batches of
//! B edges each.
//!
//! Ships synthetic stand-ins for the five SNAP temporal networks of Table 3
//! (same power-law + duplicate-edge signature, scaled down) and a loader for
//! real SNAP `u v t` files when available.

pub mod snap;

use crate::batch::BatchUpdate;
use crate::util::Rng;
use crate::graph::{GraphBuilder, VertexId};


/// A timestamped edge stream, sorted by timestamp. `|E_T|` counts duplicate
/// re-occurrences, as in Table 3.
#[derive(Debug, Clone)]
pub struct TemporalGraph {
    pub name: String,
    pub num_vertices: usize,
    /// (u, v, t) sorted ascending by t.
    pub events: Vec<(VertexId, VertexId, u64)>,
}

impl TemporalGraph {
    pub fn num_temporal_edges(&self) -> usize {
        self.events.len()
    }

    /// The paper's replay protocol: returns the base graph (first 90% of
    /// temporal edges, deduplicated, self-loops added) and an iterator-ready
    /// list of `num_batches` insertion-only batches of `batch_size` edges
    /// each, taken consecutively from the remaining stream.
    ///
    /// Batches may contain edges already present (temporal duplicates); the
    /// coordinator treats those as no-ops, exactly like the reference
    /// implementation's `addEdge`.
    pub fn replay(&self, batch_size: usize, num_batches: usize) -> (GraphBuilder, Vec<BatchUpdate>) {
        let split = (self.events.len() as f64 * 0.9) as usize;
        let mut g = GraphBuilder::new(self.num_vertices);
        for &(u, v, _) in &self.events[..split] {
            g.insert_edge(u, v);
        }
        g.ensure_self_loops();

        let mut batches = Vec::with_capacity(num_batches);
        let mut cursor = split;
        for _ in 0..num_batches {
            let end = (cursor + batch_size).min(self.events.len());
            let insertions = self.events[cursor..end]
                .iter()
                .map(|&(u, v, _)| (u, v))
                .collect();
            batches.push(BatchUpdate { deletions: Vec::new(), insertions });
            cursor = end;
            if cursor == self.events.len() {
                // wrap: re-stream from the split point (keeps 100 batches
                // meaningful even for tiny graphs / large batch fractions)
                cursor = split;
            }
        }
        (g, batches)
    }
}

/// Generate a synthetic temporal network: preferential-attachment-ish
/// endpoints (power-law), timestamps increasing, and a `dup_frac` share of
/// events that repeat an earlier edge (SNAP interaction networks re-observe
/// the same pair often — Table 3's |E_T| vs |E| gap).
pub fn generate(
    name: &str,
    n: usize,
    num_events: usize,
    dup_frac: f64,
    seed: u64,
) -> TemporalGraph {
    let mut rng = Rng::seed_from_u64(seed);
    let mut events: Vec<(VertexId, VertexId, u64)> = Vec::with_capacity(num_events);
    let mut t = 0u64;
    for i in 0..num_events {
        t += rng.gen_range_u64(1, 5);
        if i > 10 && rng.gen_f64() < dup_frac {
            // re-observe an earlier interaction
            let &(u, v, _) = &events[rng.gen_range(events.len())];
            events.push((u, v, t));
        } else {
            // power-law-ish: bias endpoints toward low ids (Zipf by squaring)
            let u = (rng.gen_f64().powi(2) * n as f64) as usize % n;
            let v = (rng.gen_f64().powi(2) * n as f64) as usize % n;
            if u == v {
                continue;
            }
            events.push((u as VertexId, v as VertexId, t));
        }
    }
    TemporalGraph { name: name.to_string(), num_vertices: n, events }
}

/// Table 3 stand-ins (scaled ~1:40 in vertices, same |E_T|/|E| duplicate
/// ratio class).
pub fn table3_standins() -> Vec<TemporalGraph> {
    vec![
        generate("sx-mathoverflow", 700, 14_000, 0.50, 201),
        generate("sx-askubuntu", 4_000, 25_000, 0.35, 202),
        generate("sx-superuser", 5_000, 36_000, 0.33, 203),
        generate("wiki-talk-temporal", 28_000, 190_000, 0.55, 204),
        generate("sx-stackoverflow", 60_000, 800_000, 0.40, 205),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_is_sorted_and_sized() {
        let tg = generate("test", 500, 5000, 0.4, 1);
        assert!(tg.events.windows(2).all(|w| w[0].2 <= w[1].2));
        assert!(tg.events.len() > 4500);
        assert!(tg.events.iter().all(|&(u, v, _)| u != v
            && (u as usize) < 500 && (v as usize) < 500));
    }

    #[test]
    fn duplicates_present() {
        let tg = generate("test", 200, 4000, 0.5, 2);
        let uniq: std::collections::HashSet<_> =
            tg.events.iter().map(|&(u, v, _)| (u, v)).collect();
        assert!(uniq.len() < tg.events.len() / 2 * 2); // strictly fewer
        assert!((uniq.len() as f64) < tg.events.len() as f64 * 0.8);
    }

    #[test]
    fn replay_protocol() {
        let tg = generate("test", 300, 10_000, 0.3, 3);
        let b = tg.num_temporal_edges() / 1000; // batch size 1e-3 |E_T|
        let (g, batches) = tg.replay(b, 100);
        assert!(g.to_csr().has_no_dead_ends());
        assert_eq!(batches.len(), 100);
        assert!(batches.iter().all(|x| x.deletions.is_empty()));
        assert!(batches.iter().all(|x| x.insertions.len() == b));
        // base graph holds ~90% of unique edges
        assert!(g.num_edges() > 300); // self-loops + bulk
    }

    #[test]
    fn standins_build() {
        for tg in table3_standins() {
            assert!(tg.num_temporal_edges() > 10_000 || tg.name == "sx-mathoverflow");
            let (g, batches) = tg.replay(tg.num_temporal_edges() / 10_000 + 1, 10);
            assert!(g.num_vertices() <= 60_000);
            assert_eq!(batches.len(), 10);
        }
    }
}
