//! Loader for SNAP temporal edge lists (`u v t` per line, whitespace
//! separated, `#` comments) — drop a real Table 3 file next to the binary
//! and the harness will use it instead of the synthetic stand-in.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read};
use std::path::Path;

use anyhow::{Context, Result};

use super::TemporalGraph;
use crate::graph::VertexId;

/// Parse a SNAP-style temporal stream. Vertex ids are remapped to a dense
/// 0..n range (SNAP files use sparse ids); events are sorted by timestamp.
pub fn parse<R: Read>(name: &str, reader: R) -> Result<TemporalGraph> {
    let mut remap: HashMap<u64, VertexId> = HashMap::new();
    let mut events = Vec::new();
    let dense = |raw: u64, remap: &mut HashMap<u64, VertexId>| -> VertexId {
        let next = remap.len() as VertexId;
        *remap.entry(raw).or_insert(next)
    };
    for (lineno, line) in BufReader::new(reader).lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut it = line.split_whitespace();
        let (u, v, t) = (|| -> Option<(u64, u64, u64)> {
            Some((
                it.next()?.parse().ok()?,
                it.next()?.parse().ok()?,
                it.next()?.parse().ok()?,
            ))
        })()
        .with_context(|| format!("bad line {} in {name}: {line:?}", lineno + 1))?;
        if u == v {
            continue; // self-interactions are re-added as managed self-loops
        }
        let du = dense(u, &mut remap);
        let dv = dense(v, &mut remap);
        events.push((du, dv, t));
    }
    events.sort_by_key(|&(_, _, t)| t);
    Ok(TemporalGraph { name: name.to_string(), num_vertices: remap.len(), events })
}

/// Load from a file path.
pub fn load(path: &Path) -> Result<TemporalGraph> {
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "snap".into());
    let f = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    parse(&name, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_and_remaps() {
        let data = "# comment\n10 20 100\n20 30 50\n10 10 60\n30 10 75\n";
        let tg = parse("x", data.as_bytes()).unwrap();
        assert_eq!(tg.num_vertices, 3);
        assert_eq!(tg.events.len(), 3); // self-interaction dropped
        // sorted by t: (20,30,50), (30,10,75), (10,20,100)
        assert_eq!(tg.events[0].2, 50);
        assert_eq!(tg.events[2].2, 100);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("x", "1 2\n".as_bytes()).is_err());
        assert!(parse("x", "a b c\n".as_bytes()).is_err());
    }

    #[test]
    fn empty_ok() {
        let tg = parse("x", "# nothing\n".as_bytes()).unwrap();
        assert_eq!(tg.num_vertices, 0);
    }
}
