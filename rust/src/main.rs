//! `pagerank-dynamic` CLI: run PageRank approaches on synthetic datasets,
//! replay temporal streams through the coordinator, and regenerate the
//! paper's tables/figures. (Offline build: hand-rolled arg parsing.)

use std::sync::Arc;

use anyhow::{bail, Result};

use pagerank_dynamic::batch::random_batch;
use pagerank_dynamic::coordinator::DynamicGraphService;
use pagerank_dynamic::engines::Approach;
use pagerank_dynamic::generators::{families, DATASETS};
use pagerank_dynamic::harness::experiments::{run_experiment, ExpOptions, Runner, Substrate};
use pagerank_dynamic::runtime::ArtifactStore;
use pagerank_dynamic::PagerankConfig;
use pagerank_dynamic::{batch, temporal};

const USAGE: &str = "\
pagerank-dynamic — Static & DF/DF-P PageRank for dynamic graphs
  (GPU-via-PJRT reproduction of Sahu 2024)

USAGE:
  pagerank-dynamic list
  pagerank-dynamic run   [--dataset NAME] [--approach static|nd|dt|df|dfp]
                         [--batch-frac F] [--native]
  pagerank-dynamic serve [--stream NAME|FILE] [--batches N] [--batch-frac F]
  pagerank-dynamic bench [--exp ID] [--full] [--out-dir DIR]
                         (IDs: table1 table2 fig1 fig3 fig4 fig6 fig7
                               fig9..fig13 all)
";

/// Tiny flag parser: `--key value` pairs after the subcommand.
struct Args {
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Self> {
        let mut flags = std::collections::HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            let Some(key) = a.strip_prefix("--") else {
                bail!("unexpected argument {a:?}\n{USAGE}");
            };
            // boolean flags
            if matches!(key, "native" | "full") {
                flags.insert(key.to_string(), "true".to_string());
                i += 1;
                continue;
            }
            let Some(val) = argv.get(i + 1) else {
                bail!("flag --{key} needs a value\n{USAGE}");
            };
            flags.insert(key.to_string(), val.clone());
            i += 2;
        }
        Ok(Self { flags })
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.flags.get(key) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

fn open_store() -> Option<Arc<ArtifactStore>> {
    match ArtifactStore::open_default() {
        Ok(s) => Some(Arc::new(s)),
        Err(e) => {
            eprintln!("warning: device artifacts unavailable ({e}); native-only mode");
            None
        }
    }
}

fn cmd_list() -> Result<()> {
    println!("Table-4 dataset stand-ins:");
    for d in DATASETS {
        let g = d.build().to_csr();
        println!(
            "  {:18} {:?}  n={:<7} m={}",
            d.name,
            d.family,
            g.num_vertices(),
            g.num_edges()
        );
    }
    println!("\nTable-3 temporal stand-ins:");
    for tg in temporal::table3_standins() {
        println!(
            "  {:20} n={:<7} |E_T|={}",
            tg.name,
            tg.num_vertices,
            tg.num_temporal_edges()
        );
    }
    if let Some(store) = open_store() {
        let m = store.manifest();
        println!(
            "\nartifact tiers ({} artifacts, kernels={}):",
            m.artifacts.len(),
            m.kernel_impl
        );
        for t in &m.tiers {
            println!(
                "  {:5} V={:<7} ECAP={:<8} W={} C={} NC={} wl={}",
                t.name, t.v, t.ecap, t.w, t.c, t.nc, t.wl_cap
            );
        }
    }
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let dataset = args.get("dataset", "it-2004");
    let Some(approach) = Approach::parse(&args.get("approach", "static")) else {
        bail!("bad --approach (static|nd|dt|df|dfp)");
    };
    let batch_frac = args.get_f64("batch-frac", 1e-5)?;
    let native = args.has("native");

    let Some(d) = families::dataset(&dataset) else {
        bail!("unknown dataset {dataset} (see `list`)")
    };
    let cfg = PagerankConfig::default();
    let store = if native { None } else { open_store() };
    let runner = Runner { store, cfg };
    let substrate = if native || runner.store.is_none() {
        Substrate::Native
    } else {
        Substrate::Device
    };

    let mut b = d.build();
    let g0 = b.to_csr();
    println!(
        "{dataset}: n={} m={} ({:?})",
        g0.num_vertices(),
        g0.num_edges(),
        substrate
    );
    let gt0 = g0.transpose();
    let prev =
        pagerank_dynamic::engines::native::static_pagerank(&g0, &gt0, &cfg, None).ranks;

    let bsize = ((g0.num_edges() as f64 * batch_frac).round() as usize).max(1);
    let upd = random_batch(&b, bsize, 0.8, 42);
    let old = b.to_csr();
    batch::apply(&mut b, &upd);
    let g = b.to_csr();
    let gt = g.transpose();

    let res = runner.run(approach, substrate, &g, &gt, &old, Some(&prev), &upd)?;
    println!(
        "{}: {} iterations in {:?} (initially affected: {})",
        approach.label(),
        res.iterations,
        res.elapsed,
        res.initially_affected
    );
    let reference = pagerank_dynamic::engines::error::reference_ranks(&g, &gt);
    println!(
        "L1 error vs reference: {:.3e}",
        pagerank_dynamic::engines::error::l1_distance(&res.ranks, &reference)?
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let stream = args.get("stream", "sx-mathoverflow");
    let num_batches = args.get_usize("batches", 20)?;
    let batch_frac = args.get_f64("batch-frac", 1e-4)?;

    let tg = if std::path::Path::new(&stream).exists() {
        temporal::snap::load(std::path::Path::new(&stream))?
    } else {
        temporal::table3_standins()
            .into_iter()
            .find(|t| t.name == stream)
            .ok_or_else(|| anyhow::anyhow!("unknown stream {stream}"))?
    };
    let bsize = ((tg.num_temporal_edges() as f64 * batch_frac).round() as usize).max(1);
    let (base, batches) = tg.replay(bsize, num_batches);
    println!(
        "serving {}: n={} base edges={} | {} batches of {}",
        tg.name,
        base.num_vertices(),
        base.num_edges(),
        batches.len(),
        bsize
    );

    // the PJRT store is created on the coordinator thread (not Send)
    let handle = pagerank_dynamic::coordinator::server::spawn(move || {
        DynamicGraphService::new(base, open_store(), PagerankConfig::default())
    });

    handle.update(Default::default())?; // initial static ranks
    for (i, upd) in batches.into_iter().enumerate() {
        let rep = handle.update(upd)?;
        println!(
            "batch {:>3}: {:5} changed via {:6} ({}) — {} iters, {:?}, affected {}",
            i + 1,
            rep.edges_changed,
            rep.approach.label(),
            if rep.on_device { "device" } else { "native" },
            rep.iterations,
            rep.elapsed,
            rep.initially_affected
        );
    }
    println!("\ntop-10 ranked vertices:");
    for (v, r) in handle.top_k(10)? {
        println!("  v{v:<8} {r:.6e}");
    }
    println!("\n{}", handle.stats()?);
    Ok(())
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        print!("{USAGE}");
        return Ok(());
    };
    let args = Args::parse(&argv[1..])?;
    match cmd.as_str() {
        "list" => cmd_list(),
        "run" => cmd_run(&args),
        "serve" => cmd_serve(&args),
        "bench" => {
            let opts = ExpOptions {
                quick: !args.has("full"),
                out_dir: args.get("out-dir", "bench_results").into(),
            };
            run_experiment(&args.get("exp", "all"), open_store(), &opts)
        }
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}
