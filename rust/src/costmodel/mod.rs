//! A100 bandwidth cost model.
//!
//! We do not have the paper's NVIDIA A100; wallclock on this testbed runs on
//! the XLA-CPU backend. PageRank is memory-bound, so the paper-scale numbers
//! are estimated from the bytes each approach moves per iteration at the
//! A100's effective HBM bandwidth — this is the standard roofline argument
//! the paper itself relies on (471 M edges/s on sk-2005 ≈ traffic-bound).
//! EXPERIMENTS.md reports both measured wallclock and these modeled times.

use std::time::Duration;

/// A100 SXM4 80 GB peak memory bandwidth (paper Section 5.1.1: 1935 GB/s).
pub const A100_PEAK_BW: f64 = 1935.0e9;
/// Achievable fraction for irregular gather traffic (~70%, the sustained
/// fraction DRAM-bound graph kernels reach on Ampere).
pub const EFFECTIVE_FRACTION: f64 = 0.70;
/// Fixed kernel-launch overhead per iteration (two kernel pairs + norm).
pub const LAUNCH_OVERHEAD: Duration = Duration::from_micros(20);

/// Bytes moved by one full (all-vertex) pull iteration: read r + contrib
/// write + r_new write + norm reads (per vertex), and per edge one 4-byte
/// CSR index + one 8-byte contribution gather.
pub fn full_iteration_bytes(n: usize, m: usize) -> f64 {
    let vertex_bytes = 8.0 * 4.0 * n as f64; // r, contrib, r_new, norm pass
    let edge_bytes = 12.0 * m as f64;
    vertex_bytes + edge_bytes
}

/// Bytes for a frontier iteration touching `affected_edges` in-edges and
/// `affected_vertices` vertices (flag reads over all V are one byte each —
/// the paper stores affected flags as 8-bit ints).
pub fn frontier_iteration_bytes(n: usize, affected_vertices: usize, affected_edges: u64) -> f64 {
    let flag_scan = n as f64; // u8 per vertex
    let vertex_bytes = 8.0 * 4.0 * affected_vertices as f64;
    let edge_bytes = 12.0 * affected_edges as f64;
    flag_scan + vertex_bytes + edge_bytes
}

/// Modeled A100 time for a run that moved `total_bytes` over `iterations`.
pub fn a100_time(total_bytes: f64, iterations: usize) -> Duration {
    let bw = A100_PEAK_BW * EFFECTIVE_FRACTION;
    Duration::from_secs_f64(total_bytes / bw) + LAUNCH_OVERHEAD * iterations as u32
}

/// Modeled time for a full-iteration approach (Static / ND / DT-upper-bound).
pub fn model_full_run(n: usize, m: usize, iterations: usize) -> Duration {
    a100_time(full_iteration_bytes(n, m) * iterations as f64, iterations)
}

/// Modeled time for a frontier approach given per-iteration affected work.
/// `per_iter` yields (affected_vertices, affected_in_edges) per iteration.
pub fn model_frontier_run(
    n: usize,
    per_iter: impl IntoIterator<Item = (usize, u64)>,
) -> Duration {
    let mut bytes = 0.0;
    let mut iters = 0;
    for (av, ae) in per_iter {
        bytes += frontier_iteration_bytes(n, av, ae);
        iters += 1;
    }
    a100_time(bytes, iters)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sk2005_scale_sanity() {
        // paper: sk-2005 (50.6M vertices, 1.98B edges) in 4.2 s at
        // tau=1e-10 — roughly 60-90 iterations. The model should land in
        // the same order of magnitude.
        let t = model_full_run(50_600_000, 1_980_000_000, 70);
        let secs = t.as_secs_f64();
        assert!(secs > 0.5 && secs < 10.0, "modeled {secs}s");
    }

    #[test]
    fn frontier_cheaper_than_full() {
        let n = 1_000_000;
        let m = 16_000_000;
        let full = model_full_run(n, m, 10);
        let frontier = model_frontier_run(n, (0..10).map(|_| (1000usize, 16_000u64)));
        assert!(frontier < full / 5);
    }

    #[test]
    fn launch_overhead_counts() {
        let a = a100_time(0.0, 100);
        assert_eq!(a, LAUNCH_OVERHEAD * 100);
    }
}
