//! Approach selection policy — the paper's Section 5.3 recommendations as
//! executable logic, extended with the watchdog's degradation ladder.
//!
//! - real-world dynamic streams: DF-P by default; switch to ND if observed
//!   error climbs above a guard band (Section 5.3.1);
//! - large random batches: DF-P up to 1e-4·|E|, ND beyond (Section 5.3.2);
//! - no previous ranks (first snapshot): Static;
//! - **health degradation**: when the rank-health watchdog rejects a
//!   result, the coordinator walks the ladder DF-P/DF/DT → ND → full
//!   Static refresh within the same update, and the policy stays in
//!   [`HealthState::Degraded`] (conservative ND) until a successful static
//!   refresh resets it.

use crate::engines::Approach;

/// Watchdog-driven policy state.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum HealthState {
    /// No unresolved watchdog trips: approach chosen on speed alone.
    #[default]
    Healthy,
    /// A recent result failed the health check: prefer ND (full-vertex
    /// processing on warm ranks) until a static refresh clears the state.
    Degraded,
}

/// Tunable policy thresholds.
#[derive(Debug, Clone)]
pub struct PolicyConfig {
    /// Batch size (as a fraction of |E|) above which ND replaces DF-P.
    pub nd_batch_fraction: f64,
    /// L1-error guard: if a calibration run reports error above this, fall
    /// back to ND for subsequent updates.
    pub error_guard: f64,
}

impl Default for PolicyConfig {
    fn default() -> Self {
        Self { nd_batch_fraction: 1e-4, error_guard: 1e-3 }
    }
}

/// Stateful policy: remembers whether the error guard tripped and whether
/// the watchdog degraded the service.
#[derive(Debug, Clone, Default)]
pub struct ApproachPolicy {
    pub config: PolicyConfig,
    error_tripped: bool,
    health: HealthState,
}

impl ApproachPolicy {
    pub fn new(config: PolicyConfig) -> Self {
        Self { config, error_tripped: false, health: HealthState::default() }
    }

    /// Choose the approach for a batch of `batch_len` edge updates against a
    /// graph with `num_edges` edges. `has_previous` is false for the first
    /// snapshot.
    pub fn choose(&self, batch_len: usize, num_edges: usize, has_previous: bool) -> Approach {
        if !has_previous {
            return Approach::Static;
        }
        if self.error_tripped || self.health == HealthState::Degraded {
            return Approach::NaiveDynamic;
        }
        let frac = batch_len as f64 / num_edges.max(1) as f64;
        if frac > self.config.nd_batch_fraction {
            Approach::NaiveDynamic
        } else {
            Approach::DynamicFrontierPruning
        }
    }

    /// The next rung of the degradation ladder after `current` failed its
    /// health check: incremental approaches fall back to ND (full-vertex
    /// processing discards poisoned frontier state but keeps the warm
    /// start), ND falls back to a full Static recompute, and a failed
    /// Static run has nowhere left to go (`None`). Marks the policy
    /// [`HealthState::Degraded`] as a side effect.
    pub fn escalate(&mut self, current: Approach) -> Option<Approach> {
        self.health = HealthState::Degraded;
        match current {
            Approach::DynamicFrontierPruning
            | Approach::DynamicFrontier
            | Approach::DynamicTraversal => Some(Approach::NaiveDynamic),
            Approach::NaiveDynamic => Some(Approach::Static),
            Approach::Static => None,
        }
    }

    pub fn health(&self) -> HealthState {
        self.health
    }

    /// Feed back an observed L1 error (from a calibration run against the
    /// reference); trips the ND fallback per the paper's recommendation.
    pub fn observe_error(&mut self, l1_error: f64) {
        if l1_error > self.config.error_guard {
            self.error_tripped = true;
        }
    }

    pub fn error_tripped(&self) -> bool {
        self.error_tripped
    }

    /// Reset the error guard and health degradation (after a successful
    /// full static refresh: fresh ranks carry no poisoned state).
    pub fn reset(&mut self) {
        self.error_tripped = false;
        self.health = HealthState::Healthy;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_snapshot_is_static() {
        let p = ApproachPolicy::default();
        assert_eq!(p.choose(10, 1000, false), Approach::Static);
    }

    #[test]
    fn small_batches_use_dfp_large_use_nd() {
        let p = ApproachPolicy::default();
        assert_eq!(p.choose(1, 1_000_000, true), Approach::DynamicFrontierPruning);
        assert_eq!(p.choose(10_000, 1_000_000, true), Approach::NaiveDynamic);
    }

    #[test]
    fn error_guard_trips_and_resets() {
        let mut p = ApproachPolicy::default();
        p.observe_error(1e-5);
        assert_eq!(p.choose(1, 1_000_000, true), Approach::DynamicFrontierPruning);
        p.observe_error(0.5);
        assert!(p.error_tripped());
        assert_eq!(p.choose(1, 1_000_000, true), Approach::NaiveDynamic);
        p.reset();
        assert_eq!(p.choose(1, 1_000_000, true), Approach::DynamicFrontierPruning);
    }

    #[test]
    fn degradation_ladder_walks_dfp_nd_static() {
        let mut p = ApproachPolicy::default();
        assert_eq!(p.health(), HealthState::Healthy);
        assert_eq!(
            p.escalate(Approach::DynamicFrontierPruning),
            Some(Approach::NaiveDynamic)
        );
        assert_eq!(p.escalate(Approach::NaiveDynamic), Some(Approach::Static));
        assert_eq!(p.escalate(Approach::Static), None, "ladder bottoms out");
        assert_eq!(p.escalate(Approach::DynamicFrontier), Some(Approach::NaiveDynamic));
        assert_eq!(p.escalate(Approach::DynamicTraversal), Some(Approach::NaiveDynamic));
    }

    #[test]
    fn degraded_policy_prefers_nd_until_reset() {
        let mut p = ApproachPolicy::default();
        p.escalate(Approach::DynamicFrontierPruning);
        assert_eq!(p.health(), HealthState::Degraded);
        assert_eq!(p.choose(1, 1_000_000, true), Approach::NaiveDynamic);
        assert_eq!(p.choose(1, 1_000_000, false), Approach::Static, "first snapshot wins");
        p.reset();
        assert_eq!(p.health(), HealthState::Healthy);
        assert_eq!(p.choose(1, 1_000_000, true), Approach::DynamicFrontierPruning);
    }
}
