//! Approach selection policy — the paper's Section 5.3 recommendations as
//! executable logic.
//!
//! - real-world dynamic streams: DF-P by default; switch to ND if observed
//!   error climbs above a guard band (Section 5.3.1);
//! - large random batches: DF-P up to 1e-4·|E|, ND beyond (Section 5.3.2);
//! - no previous ranks (first snapshot): Static.

use crate::engines::Approach;

/// Tunable policy thresholds.
#[derive(Debug, Clone)]
pub struct PolicyConfig {
    /// Batch size (as a fraction of |E|) above which ND replaces DF-P.
    pub nd_batch_fraction: f64,
    /// L1-error guard: if a calibration run reports error above this, fall
    /// back to ND for subsequent updates.
    pub error_guard: f64,
}

impl Default for PolicyConfig {
    fn default() -> Self {
        Self { nd_batch_fraction: 1e-4, error_guard: 1e-3 }
    }
}

/// Stateful policy: remembers whether the error guard tripped.
#[derive(Debug, Clone, Default)]
pub struct ApproachPolicy {
    pub config: PolicyConfig,
    error_tripped: bool,
}

impl ApproachPolicy {
    pub fn new(config: PolicyConfig) -> Self {
        Self { config, error_tripped: false }
    }

    /// Choose the approach for a batch of `batch_len` edge updates against a
    /// graph with `num_edges` edges. `has_previous` is false for the first
    /// snapshot.
    pub fn choose(&self, batch_len: usize, num_edges: usize, has_previous: bool) -> Approach {
        if !has_previous {
            return Approach::Static;
        }
        if self.error_tripped {
            return Approach::NaiveDynamic;
        }
        let frac = batch_len as f64 / num_edges.max(1) as f64;
        if frac > self.config.nd_batch_fraction {
            Approach::NaiveDynamic
        } else {
            Approach::DynamicFrontierPruning
        }
    }

    /// Feed back an observed L1 error (from a calibration run against the
    /// reference); trips the ND fallback per the paper's recommendation.
    pub fn observe_error(&mut self, l1_error: f64) {
        if l1_error > self.config.error_guard {
            self.error_tripped = true;
        }
    }

    pub fn error_tripped(&self) -> bool {
        self.error_tripped
    }

    /// Reset the guard (e.g. after a periodic full static refresh).
    pub fn reset(&mut self) {
        self.error_tripped = false;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_snapshot_is_static() {
        let p = ApproachPolicy::default();
        assert_eq!(p.choose(10, 1000, false), Approach::Static);
    }

    #[test]
    fn small_batches_use_dfp_large_use_nd() {
        let p = ApproachPolicy::default();
        assert_eq!(p.choose(1, 1_000_000, true), Approach::DynamicFrontierPruning);
        assert_eq!(p.choose(10_000, 1_000_000, true), Approach::NaiveDynamic);
    }

    #[test]
    fn error_guard_trips_and_resets() {
        let mut p = ApproachPolicy::default();
        p.observe_error(1e-5);
        assert_eq!(p.choose(1, 1_000_000, true), Approach::DynamicFrontierPruning);
        p.observe_error(0.5);
        assert!(p.error_tripped());
        assert_eq!(p.choose(1, 1_000_000, true), Approach::NaiveDynamic);
        p.reset();
        assert_eq!(p.choose(1, 1_000_000, true), Approach::DynamicFrontierPruning);
    }
}
