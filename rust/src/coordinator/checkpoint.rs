//! Checkpoint/restore: periodic snapshots of (edge list, ranks, metrics,
//! config) so a restarted — or supervisor-respawned — coordinator resumes
//! warm from its last good state instead of recomputing from scratch.
//!
//! Two forms:
//! * **in-memory** ([`Checkpoint`]): cloned into the server's shared slot
//!   after updates; the supervisor rebuilds a panicked coordinator from it
//!   (see [`super::server`]). It carries the full [`Metrics`] so counters
//!   survive a respawn.
//! * **JSON** ([`Checkpoint::to_json`] / [`Checkpoint::from_json`], via the
//!   offline [`crate::util::json`] substrate): for persistence across
//!   process restarts. Rust's shortest-roundtrip float formatting keeps the
//!   rank bits exact across a serialize/parse cycle. Untrusted documents
//!   are validated on load — out-of-range edges, wrong-length or non-finite
//!   ranks are typed errors, never a panic downstream.

use std::collections::HashSet;
use std::fmt::Write as _;

use anyhow::{bail, Context, Result};

use super::health::{check_ranks, HealthConfig, HealthError};
use super::metrics::Metrics;
use crate::engines::config::PagerankConfig;
use crate::graph::VertexId;
use crate::util::json::{self, Value};

/// A consistent snapshot of the coordinator's evolving state.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    /// Update sequence number at capture time (monotone per service).
    pub seq: u64,
    pub num_vertices: usize,
    /// Every edge of the builder, self-loops included.
    pub edges: Vec<(VertexId, VertexId)>,
    /// Delta reconstructing the *previous* snapshot (the `prev_csr` that
    /// Dynamic Traversal BFSes over) from `edges`: edges the current graph
    /// has that the previous snapshot lacked. Sorted for a deterministic
    /// document.
    pub prev_missing: Vec<(VertexId, VertexId)>,
    /// The other half of the delta: edges the previous snapshot had that
    /// the current graph lost (deletions applied by the last batch).
    pub prev_extra: Vec<(VertexId, VertexId)>,
    /// Last-known-good ranks (`None` before the first computation).
    pub ranks: Option<Vec<f64>>,
    /// The serving configuration (restored services keep behaving the same).
    pub cfg: PagerankConfig,
    /// Serving counters at capture time.
    pub metrics: Metrics,
}

impl Checkpoint {
    /// Structural validation: every edge in range, ranks (if present) the
    /// right length and finite. A checkpoint that fails this must not be
    /// restored — it would re-poison the service it is meant to heal.
    pub fn validate(&self) -> Result<()> {
        let n = self.num_vertices;
        if let Some((u, v)) = self
            .edges
            .iter()
            .chain(&self.prev_missing)
            .chain(&self.prev_extra)
            .find(|&&(u, v)| u as usize >= n || v as usize >= n)
        {
            bail!("checkpoint edge ({u}, {v}) out of range for {n} vertices");
        }
        if let Some(r) = &self.ranks {
            // converged state: iteration count 0 never trips the cap check
            let violations =
                check_ranks(r, n, 0, &self.cfg, &HealthConfig::default());
            if !violations.is_empty() {
                return Err(HealthError(violations).into());
            }
        }
        self.cfg.validate().context("checkpoint config")?;
        Ok(())
    }

    /// The previous snapshot's edge set (`prev_csr` at capture time),
    /// reconstructed as `edges − prev_missing + prev_extra`, sorted.
    /// Order is irrelevant to DT — the snapshot only drives a reachability
    /// BFS — but sorting keeps restores deterministic.
    pub fn prev_edges(&self) -> Vec<(VertexId, VertexId)> {
        let missing: HashSet<(VertexId, VertexId)> =
            self.prev_missing.iter().copied().collect();
        let mut prev: Vec<(VertexId, VertexId)> =
            self.edges.iter().copied().filter(|e| !missing.contains(e)).collect();
        prev.extend(self.prev_extra.iter().copied());
        prev.sort_unstable();
        prev.dedup();
        prev
    }

    /// Serialize to a single JSON document.
    pub fn to_json(&self) -> String {
        let mut s = String::with_capacity(32 + self.edges.len() * 8);
        let _ = write!(
            s,
            "{{\"format\":1,\"seq\":{},\"num_vertices\":{},\"cfg\":{{\"alpha\":{},\"tau\":{},\"tau_frontier\":{},\"tau_prune\":{},\"max_iterations\":{},\"threads\":{},\"pool_persistent\":{},\"simd\":\"{}\",\"csr\":\"{}\"}}",
            self.seq,
            self.num_vertices,
            self.cfg.alpha,
            self.cfg.tau,
            self.cfg.tau_frontier,
            self.cfg.tau_prune,
            self.cfg.max_iterations,
            self.cfg.threads,
            self.cfg.pool_persistent,
            self.cfg.simd.as_str(),
            self.cfg.csr_mode.as_str()
        );
        s.push_str(",\"edges\":");
        write_edge_pairs(&mut s, &self.edges);
        s.push_str(",\"prev_missing\":");
        write_edge_pairs(&mut s, &self.prev_missing);
        s.push_str(",\"prev_extra\":");
        write_edge_pairs(&mut s, &self.prev_extra);
        match &self.ranks {
            None => s.push_str(",\"ranks\":null"),
            Some(r) => {
                s.push_str(",\"ranks\":[");
                for (i, x) in r.iter().enumerate() {
                    if i > 0 {
                        s.push(',');
                    }
                    let _ = write!(s, "{x}");
                }
                s.push(']');
            }
        }
        let m = &self.metrics;
        let _ = write!(
            s,
            ",\"counters\":{{\"updates_applied\":{},\"edges_inserted\":{},\"edges_deleted\":{},\"device_runs\":{},\"native_fallbacks\":{},\"quarantined_edits\":{},\"watchdog_trips\":{},\"health_recoveries\":{},\"restores\":{},\"maintenance_ns\":{}}}}}",
            m.updates_applied,
            m.edges_inserted,
            m.edges_deleted,
            m.device_runs,
            m.native_fallbacks,
            m.quarantined_edits,
            m.watchdog_trips,
            m.health_recoveries,
            m.restores,
            m.maintenance_ns
        );
        s
    }

    /// Parse and validate a JSON checkpoint. Per-approach latency stats are
    /// not persisted; scalar counters are.
    pub fn from_json(src: &str) -> Result<Checkpoint> {
        let v = json::parse(src).context("checkpoint parse")?;
        let format = v.get("format")?.as_usize()?;
        if format != 1 {
            bail!("unsupported checkpoint format {format}");
        }
        let seq = v.get("seq")?.as_usize()? as u64;
        let num_vertices = v.get("num_vertices")?.as_usize()?;
        let c = v.get("cfg")?;
        let cfg = PagerankConfig {
            alpha: c.get("alpha")?.as_f64()?,
            tau: c.get("tau")?.as_f64()?,
            tau_frontier: c.get("tau_frontier")?.as_f64()?,
            tau_prune: c.get("tau_prune")?.as_f64()?,
            max_iterations: c.get("max_iterations")?.as_usize()?,
            threads: c.get("threads")?.as_usize()?,
            pool_persistent: c.get("pool_persistent")?.as_bool()?,
            // absent in pre-SIMD documents (still format 1): default Auto
            simd: c
                .get("simd")
                .ok()
                .and_then(|s| s.as_str().ok())
                .and_then(crate::util::SimdPolicy::parse)
                .unwrap_or_default(),
            // absent in pre-incremental-CSR documents: default Auto
            csr_mode: c
                .get("csr")
                .ok()
                .and_then(|s| s.as_str().ok())
                .and_then(crate::graph::CsrMode::parse)
                .unwrap_or_default(),
        };
        let edges = parse_edge_pairs(&v, "edges")?;
        let prev_missing = parse_edge_pairs(&v, "prev_missing")?;
        let prev_extra = parse_edge_pairs(&v, "prev_extra")?;
        let ranks = match v.get("ranks")? {
            Value::Null => None,
            Value::Arr(a) => {
                let mut r = Vec::with_capacity(a.len());
                for x in a {
                    r.push(x.as_f64()?);
                }
                Some(r)
            }
            _ => bail!("checkpoint ranks must be an array or null"),
        };
        let mut metrics = Metrics::default();
        let k = v.get("counters")?;
        metrics.updates_applied = k.get("updates_applied")?.as_usize()?;
        metrics.edges_inserted = k.get("edges_inserted")?.as_usize()?;
        metrics.edges_deleted = k.get("edges_deleted")?.as_usize()?;
        metrics.device_runs = k.get("device_runs")?.as_usize()?;
        metrics.native_fallbacks = k.get("native_fallbacks")?.as_usize()?;
        metrics.quarantined_edits = k.get("quarantined_edits")?.as_usize()?;
        metrics.watchdog_trips = k.get("watchdog_trips")?.as_usize()?;
        metrics.health_recoveries = k.get("health_recoveries")?.as_usize()?;
        metrics.restores = k.get("restores")?.as_usize()?;
        // absent in pre-incremental-CSR documents: counter starts at zero
        metrics.maintenance_ns =
            k.get("maintenance_ns").ok().and_then(|x| x.as_usize().ok()).unwrap_or(0) as u64;

        let cp = Checkpoint {
            seq,
            num_vertices,
            edges,
            prev_missing,
            prev_extra,
            ranks,
            cfg,
            metrics,
        };
        cp.validate()?;
        Ok(cp)
    }
}

fn write_edge_pairs(s: &mut String, edges: &[(VertexId, VertexId)]) {
    s.push('[');
    for (i, (u, v)) in edges.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        let _ = write!(s, "{u},{v}");
    }
    s.push(']');
}

fn parse_edge_pairs(v: &Value, key: &str) -> Result<Vec<(VertexId, VertexId)>> {
    let flat = v.get(key)?.as_arr()?;
    if flat.len() % 2 != 0 {
        bail!("checkpoint {key} array has odd length {}", flat.len());
    }
    let mut edges = Vec::with_capacity(flat.len() / 2);
    for pair in flat.chunks_exact(2) {
        let u = pair[0].as_usize()?;
        let w = pair[1].as_usize()?;
        if u > VertexId::MAX as usize || w > VertexId::MAX as usize {
            bail!("checkpoint {key} edge ({u}, {w}) exceeds vertex id range");
        }
        edges.push((u as VertexId, w as VertexId));
    }
    Ok(edges)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        let mut metrics = Metrics::default();
        metrics.record_update(3, 1);
        metrics.record_quarantined(2);
        metrics.record_watchdog_trip();
        Checkpoint {
            seq: 7,
            num_vertices: 3,
            edges: vec![(0, 1), (1, 2), (0, 0), (1, 1), (2, 2)],
            // previous snapshot: had (2, 1), did not yet have (0, 1)
            prev_missing: vec![(0, 1)],
            prev_extra: vec![(2, 1)],
            ranks: Some(vec![0.25, 0.5, 0.25]),
            cfg: PagerankConfig::default().with_threads(2),
            metrics,
        }
    }

    #[test]
    fn json_roundtrip_is_exact() {
        let cp = sample();
        let back = Checkpoint::from_json(&cp.to_json()).unwrap();
        assert_eq!(back.seq, 7);
        assert_eq!(back.num_vertices, 3);
        assert_eq!(back.edges, cp.edges);
        assert_eq!(back.cfg, cp.cfg);
        let (a, b) = (back.ranks.unwrap(), cp.ranks.unwrap());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits(), "bit-exact rank roundtrip");
        }
        assert_eq!(back.metrics.updates_applied, 1);
        assert_eq!(back.metrics.quarantined_edits, 2);
        assert_eq!(back.metrics.watchdog_trips, 1);
    }

    #[test]
    fn roundtrip_preserves_awkward_floats() {
        let mut cp = sample();
        cp.ranks = Some(vec![1.0 / 3.0, 1e-17 + 0.5, 0.5 - 1e-17 - 1.0 / 3.0]);
        // not mass-1: widen via no ranks validation path — keep mass valid
        let s: f64 = cp.ranks.as_ref().unwrap().iter().sum();
        cp.ranks.as_mut().unwrap()[0] += 1.0 - s;
        let back = Checkpoint::from_json(&cp.to_json()).unwrap();
        for (x, y) in back.ranks.unwrap().iter().zip(cp.ranks.as_ref().unwrap()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn prev_delta_roundtrips_and_reconstructs() {
        let cp = sample();
        let back = Checkpoint::from_json(&cp.to_json()).unwrap();
        assert_eq!(back.prev_missing, cp.prev_missing);
        assert_eq!(back.prev_extra, cp.prev_extra);
        assert_eq!(
            back.prev_edges(),
            vec![(0, 0), (1, 1), (1, 2), (2, 1), (2, 2)],
            "previous snapshot = current − missing + extra, sorted"
        );
        // out-of-range delta edges are rejected like regular edges
        let mut bad = sample();
        bad.prev_extra.push((9, 0));
        assert!(bad.validate().is_err());
    }

    #[test]
    fn simd_policy_roundtrips_and_old_documents_default() {
        use crate::util::SimdPolicy;
        let mut cp = sample();
        cp.cfg = cp.cfg.with_simd(SimdPolicy::Scalar);
        let back = Checkpoint::from_json(&cp.to_json()).unwrap();
        assert_eq!(back.cfg.simd, SimdPolicy::Scalar);
        // pre-SIMD documents (format 1, no "simd" key) stay loadable and
        // fall back to the Auto default
        let doc = cp.to_json().replace(",\"simd\":\"scalar\"", "");
        assert!(!doc.contains("simd"));
        let back = Checkpoint::from_json(&doc).unwrap();
        assert_eq!(back.cfg.simd, SimdPolicy::Auto);
    }

    #[test]
    fn csr_mode_and_maintenance_roundtrip_and_old_documents_default() {
        use crate::graph::CsrMode;
        use std::time::Duration;
        let mut cp = sample();
        cp.cfg = cp.cfg.with_csr_mode(CsrMode::Rebuild);
        cp.metrics.record_maintenance(Duration::from_nanos(12_345));
        let back = Checkpoint::from_json(&cp.to_json()).unwrap();
        assert_eq!(back.cfg.csr_mode, CsrMode::Rebuild);
        assert_eq!(back.metrics.maintenance_ns, 12_345);
        // pre-incremental-CSR documents (format 1, no "csr"/"maintenance_ns"
        // keys) stay loadable and fall back to the defaults
        let doc = cp
            .to_json()
            .replace(",\"csr\":\"rebuild\"", "")
            .replace(",\"maintenance_ns\":12345", "");
        assert!(!doc.contains("csr") && !doc.contains("maintenance_ns"));
        let back = Checkpoint::from_json(&doc).unwrap();
        assert_eq!(back.cfg.csr_mode, CsrMode::Auto);
        assert_eq!(back.metrics.maintenance_ns, 0);
    }

    #[test]
    fn none_ranks_roundtrip() {
        let mut cp = sample();
        cp.ranks = None;
        let back = Checkpoint::from_json(&cp.to_json()).unwrap();
        assert!(back.ranks.is_none());
    }

    #[test]
    fn poisoned_checkpoints_are_rejected() {
        // NaN rank
        let mut cp = sample();
        cp.ranks.as_mut().unwrap()[0] = f64::NAN;
        assert!(cp.validate().is_err());
        // out-of-range edge
        let mut cp = sample();
        cp.edges.push((9, 0));
        assert!(cp.validate().is_err());
        // wrong-length ranks
        let mut cp = sample();
        cp.ranks.as_mut().unwrap().push(0.0);
        assert!(cp.validate().is_err());
        // mass drift
        let mut cp = sample();
        cp.ranks = Some(vec![1.0, 1.0, 1.0]);
        assert!(cp.validate().is_err());
        // garbage document
        assert!(Checkpoint::from_json("{\"format\":1").is_err());
        assert!(Checkpoint::from_json("{\"format\":2}").is_err());
    }
}
