//! Rank-health watchdog: after every engine run the coordinator checks the
//! returned ranks before installing them. PageRank invariants are cheap to
//! verify — every rank is finite and non-negative, the total rank mass is 1
//! (the iteration is a stochastic-matrix fixpoint), and the run converged
//! under its iteration cap — and a violation means the result is garbage
//! (device fault, kernel bug, poisoned warm-start state, injected fault).
//!
//! A tripped check never crashes the service and never serves the bad
//! vector: the coordinator keeps answering from the last-known-good ranks
//! and escalates through the degradation ladder (DF-P → ND → full Static
//! refresh, see [`super::policy`]) until a healthy result is produced.

use std::fmt;

use crate::engines::config::PagerankConfig;

/// Watchdog thresholds.
#[derive(Debug, Clone, Copy)]
pub struct HealthConfig {
    /// Allowed |Σr − 1| drift. DF-P deliberately trades accuracy for speed
    /// (paper Section 5.3), so the default is looser than τ but far tighter
    /// than the policy's 1e-3 error guard.
    pub mass_tolerance: f64,
    /// Flag runs that hit the iteration cap without converging.
    pub check_convergence: bool,
}

impl Default for HealthConfig {
    fn default() -> Self {
        Self { mass_tolerance: 1e-4, check_convergence: true }
    }
}

/// One tripped invariant.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum HealthViolation {
    /// NaN or ±Inf ranks.
    NonFinite { count: usize },
    /// Strictly negative ranks (impossible under Eq. 1).
    Negative { count: usize },
    /// |Σr − 1| beyond [`HealthConfig::mass_tolerance`].
    MassDrift { mass: f64 },
    /// The run used every allowed iteration without reaching τ.
    NonConvergence { iterations: usize },
    /// The engine returned a vector of the wrong length.
    WrongLength { got: usize, want: usize },
}

impl fmt::Display for HealthViolation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            HealthViolation::NonFinite { count } => {
                write!(f, "{count} non-finite rank(s)")
            }
            HealthViolation::Negative { count } => {
                write!(f, "{count} negative rank(s)")
            }
            HealthViolation::MassDrift { mass } => {
                write!(f, "rank mass {mass} drifted from 1")
            }
            HealthViolation::NonConvergence { iterations } => {
                write!(f, "no convergence after {iterations} iterations")
            }
            HealthViolation::WrongLength { got, want } => {
                write!(f, "rank vector has {got} entries, graph has {want}")
            }
        }
    }
}

/// All violations from one check, as a typed error (`?`-converts to
/// `anyhow::Error`).
#[derive(Debug, Clone, PartialEq)]
pub struct HealthError(pub Vec<HealthViolation>);

impl fmt::Display for HealthError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "rank health check failed: ")?;
        for (i, v) in self.0.iter().enumerate() {
            if i > 0 {
                write!(f, "; ")?;
            }
            write!(f, "{v}")?;
        }
        Ok(())
    }
}

impl std::error::Error for HealthError {}

/// Check one engine result against the watchdog invariants. Returns every
/// violation found (empty = healthy). `iterations` is the number the engine
/// actually ran; `num_vertices` the size the vector must have.
pub fn check_ranks(
    ranks: &[f64],
    num_vertices: usize,
    iterations: usize,
    cfg: &PagerankConfig,
    hc: &HealthConfig,
) -> Vec<HealthViolation> {
    let mut out = Vec::new();
    if ranks.len() != num_vertices {
        out.push(HealthViolation::WrongLength { got: ranks.len(), want: num_vertices });
        return out; // nothing else is meaningful on a wrong-shape vector
    }
    let mut non_finite = 0usize;
    let mut negative = 0usize;
    let mut mass = 0.0f64;
    for &r in ranks {
        if !r.is_finite() {
            non_finite += 1;
        } else if r < 0.0 {
            negative += 1;
        }
        mass += r;
    }
    if non_finite > 0 {
        out.push(HealthViolation::NonFinite { count: non_finite });
    }
    if negative > 0 {
        out.push(HealthViolation::Negative { count: negative });
    }
    // only meaningful when every summand was finite (otherwise NonFinite
    // already covers it); a sum that overflowed still exceeds the tolerance
    if non_finite == 0 && (mass - 1.0).abs() > hc.mass_tolerance {
        out.push(HealthViolation::MassDrift { mass });
    }
    if hc.check_convergence && iterations >= cfg.max_iterations {
        out.push(HealthViolation::NonConvergence { iterations });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> PagerankConfig {
        PagerankConfig::default()
    }

    #[test]
    fn healthy_ranks_pass() {
        let r = vec![0.25; 4];
        assert!(check_ranks(&r, 4, 30, &cfg(), &HealthConfig::default()).is_empty());
    }

    #[test]
    fn nan_and_inf_detected() {
        let r = vec![0.25, f64::NAN, 0.25, f64::INFINITY];
        let v = check_ranks(&r, 4, 30, &cfg(), &HealthConfig::default());
        assert!(v.contains(&HealthViolation::NonFinite { count: 2 }), "{v:?}");
    }

    #[test]
    fn negative_detected() {
        let r = vec![0.6, -0.1, 0.5];
        let v = check_ranks(&r, 3, 30, &cfg(), &HealthConfig::default());
        assert!(v.contains(&HealthViolation::Negative { count: 1 }), "{v:?}");
    }

    #[test]
    fn mass_drift_detected() {
        let r = vec![0.5; 4]; // mass 2.0
        let v = check_ranks(&r, 4, 30, &cfg(), &HealthConfig::default());
        assert!(matches!(v[0], HealthViolation::MassDrift { mass } if (mass - 2.0).abs() < 1e-12));
        // within tolerance passes
        let hc = HealthConfig { mass_tolerance: 1.5, ..Default::default() };
        assert!(check_ranks(&r, 4, 30, &cfg(), &hc).is_empty());
    }

    #[test]
    fn iteration_cap_detected_and_optional() {
        let r = vec![0.25; 4];
        let v = check_ranks(&r, 4, 500, &cfg(), &HealthConfig::default());
        assert_eq!(v, vec![HealthViolation::NonConvergence { iterations: 500 }]);
        let hc = HealthConfig { check_convergence: false, ..Default::default() };
        assert!(check_ranks(&r, 4, 500, &cfg(), &hc).is_empty());
    }

    #[test]
    fn wrong_length_short_circuits() {
        let r = vec![f64::NAN; 3];
        let v = check_ranks(&r, 4, 30, &cfg(), &HealthConfig::default());
        assert_eq!(v, vec![HealthViolation::WrongLength { got: 3, want: 4 }]);
    }

    #[test]
    fn error_formats_all_violations() {
        let e = HealthError(vec![
            HealthViolation::NonFinite { count: 2 },
            HealthViolation::MassDrift { mass: f64::NAN },
        ]);
        let s = e.to_string();
        assert!(s.contains("non-finite") && s.contains("drifted"), "{s}");
    }
}
