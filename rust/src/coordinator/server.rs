//! Request front-end for the coordinator.
//!
//! The service is single-writer (it owns the evolving graph), so requests
//! are serialized through a **bounded** mpsc channel into a dedicated
//! thread (PJRT execution is synchronous); clients get a cheap cloneable
//! [`CoordinatorHandle`]. This is the "leader" loop of the L3 architecture:
//! update producers and rank readers never touch the graph state directly.
//!
//! # Resilience
//!
//! * **Backpressure** — the queue is a `sync_channel` of
//!   [`ServerConfig::queue_capacity`]; blocking methods wait, the
//!   `*_with_deadline` variants return the typed
//!   [`ServerError::Backpressure`] instead of queueing unboundedly.
//! * **Deadlines** — `*_with_deadline` methods attach a deadline; a request
//!   that expires in the queue is shed by the coordinator without doing the
//!   work, and the client call returns [`ServerError::DeadlineExceeded`].
//! * **Supervision** — the coordinator loop runs under `catch_unwind`; if
//!   the service panics (device fault, injected kill), a supervisor
//!   respawns it from the last checkpoint
//!   ([`DynamicGraphService::restore`], store-less, so it serves from the
//!   native engines) and keeps answering. Only the in-flight request is
//!   lost ([`ServerError::Dropped`] — safe to retry).
//! * **Checkpoints** — taken automatically every
//!   [`ServerConfig::checkpoint_every`] updates (and on the first), and on
//!   demand via [`CoordinatorHandle::checkpoint_now`].

use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use super::{Checkpoint, DynamicGraphService, UpdateReport};
use crate::batch::BatchUpdate;
use crate::graph::VertexId;

/// Typed failures of the serving front-end.
#[derive(Debug, Clone, PartialEq)]
pub enum ServerError {
    /// The bounded request queue is full; shed load or retry later.
    Backpressure { capacity: usize },
    /// The request missed its deadline (shed in-queue by the coordinator,
    /// or timed out waiting for the response).
    DeadlineExceeded,
    /// The coordinator has shut down (all handles dropped, it could not be
    /// built, or the respawn limit was exhausted).
    Stopped,
    /// The coordinator died while holding this request; a respawn is in
    /// flight and the request is safe to retry.
    Dropped,
    /// The service executed the request and reported an error (e.g. an
    /// unrecoverable health-check failure). Last-known-good ranks are still
    /// being served.
    Rejected(String),
}

impl fmt::Display for ServerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServerError::Backpressure { capacity } => {
                write!(f, "request queue full ({capacity} slots)")
            }
            ServerError::DeadlineExceeded => write!(f, "request deadline exceeded"),
            ServerError::Stopped => write!(f, "coordinator stopped"),
            ServerError::Dropped => {
                write!(f, "coordinator dropped request (respawn in flight; retry)")
            }
            ServerError::Rejected(msg) => write!(f, "request rejected: {msg}"),
        }
    }
}

impl std::error::Error for ServerError {}

/// Front-end tunables.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Bounded queue depth; senders beyond this block (or get
    /// [`ServerError::Backpressure`] on the deadline paths).
    pub queue_capacity: usize,
    /// Checkpoint after every N successful updates.
    pub checkpoint_every: u64,
    /// Give up respawning after this many panics.
    pub respawn_limit: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self { queue_capacity: 64, checkpoint_every: 4, respawn_limit: 8 }
    }
}

enum Request {
    Update(BatchUpdate, mpsc::Sender<Result<UpdateReport, ServerError>>),
    TopK(usize, mpsc::Sender<Vec<(VertexId, f64)>>),
    RanksOf(Vec<VertexId>, mpsc::Sender<Vec<f64>>),
    Stats(mpsc::Sender<String>),
    RefreshStatic(mpsc::Sender<Result<UpdateReport, ServerError>>),
    Checkpoint(mpsc::Sender<u64>),
}

struct Envelope {
    deadline: Option<Instant>,
    req: Request,
}

#[derive(Default)]
struct Shared {
    checkpoint: Mutex<Option<Checkpoint>>,
    respawns: AtomicUsize,
}

impl Shared {
    fn checkpoint_slot(&self) -> std::sync::MutexGuard<'_, Option<Checkpoint>> {
        // a panic can never poison this lock meaningfully: the slot only
        // ever holds complete, validated snapshots
        self.checkpoint.lock().unwrap_or_else(|p| p.into_inner())
    }
}

/// Cloneable handle to a running coordinator. Blocking methods wait for the
/// coordinator (requests are processed in FIFO order); `*_with_deadline`
/// variants bound both queueing and waiting with typed errors.
#[derive(Clone)]
pub struct CoordinatorHandle {
    tx: mpsc::SyncSender<Envelope>,
    shared: Arc<Shared>,
    capacity: usize,
}

impl CoordinatorHandle {
    fn call<T>(
        &self,
        make: impl FnOnce(mpsc::Sender<T>) -> Request,
    ) -> Result<T, ServerError> {
        let (tx, rx) = mpsc::channel();
        let env = Envelope { deadline: None, req: make(tx) };
        self.tx.send(env).map_err(|_| ServerError::Stopped)?;
        rx.recv().map_err(|_| ServerError::Dropped)
    }

    fn call_with_deadline<T>(
        &self,
        timeout: Duration,
        make: impl FnOnce(mpsc::Sender<T>) -> Request,
    ) -> Result<T, ServerError> {
        let (tx, rx) = mpsc::channel();
        let env = Envelope { deadline: Some(Instant::now() + timeout), req: make(tx) };
        match self.tx.try_send(env) {
            Ok(()) => {}
            Err(mpsc::TrySendError::Full(_)) => {
                return Err(ServerError::Backpressure { capacity: self.capacity })
            }
            Err(mpsc::TrySendError::Disconnected(_)) => return Err(ServerError::Stopped),
        }
        match rx.recv_timeout(timeout) {
            Ok(v) => Ok(v),
            Err(mpsc::RecvTimeoutError::Timeout) => Err(ServerError::DeadlineExceeded),
            Err(mpsc::RecvTimeoutError::Disconnected) => Err(ServerError::Dropped),
        }
    }

    /// Apply a batch update; returns once ranks are refreshed.
    pub fn update(&self, batch: BatchUpdate) -> Result<UpdateReport, ServerError> {
        self.call(|tx| Request::Update(batch, tx))?
    }

    /// Apply a batch update with a deadline: fails fast with
    /// [`ServerError::Backpressure`] when the queue is full and
    /// [`ServerError::DeadlineExceeded`] when the coordinator cannot answer
    /// in time (expired requests are shed without being executed).
    pub fn update_with_deadline(
        &self,
        batch: BatchUpdate,
        timeout: Duration,
    ) -> Result<UpdateReport, ServerError> {
        self.call_with_deadline(timeout, |tx| Request::Update(batch, tx))?
    }

    /// Highest-ranked vertices.
    pub fn top_k(&self, k: usize) -> Result<Vec<(VertexId, f64)>, ServerError> {
        self.call(|tx| Request::TopK(k, tx))
    }

    /// Highest-ranked vertices, bounded wait.
    pub fn top_k_with_deadline(
        &self,
        k: usize,
        timeout: Duration,
    ) -> Result<Vec<(VertexId, f64)>, ServerError> {
        self.call_with_deadline(timeout, |tx| Request::TopK(k, tx))
    }

    /// Ranks of specific vertices (0.0 if not yet computed / out of range).
    pub fn ranks_of(&self, vertices: Vec<VertexId>) -> Result<Vec<f64>, ServerError> {
        self.call(|tx| Request::RanksOf(vertices, tx))
    }

    /// Metrics summary line (includes the health counters).
    pub fn stats(&self) -> Result<String, ServerError> {
        self.call(Request::Stats)
    }

    /// Force a full static refresh.
    pub fn refresh_static(&self) -> Result<UpdateReport, ServerError> {
        self.call(Request::RefreshStatic)?
    }

    /// Take a checkpoint right now; returns its sequence number.
    pub fn checkpoint_now(&self) -> Result<u64, ServerError> {
        self.call(Request::Checkpoint)
    }

    /// The most recent checkpoint, if one has been taken.
    pub fn last_checkpoint(&self) -> Option<Checkpoint> {
        self.shared.checkpoint_slot().clone()
    }

    /// How many times the supervisor has respawned the coordinator.
    pub fn respawns(&self) -> usize {
        self.shared.respawns.load(Ordering::SeqCst)
    }
}

fn store_checkpoint(service: &DynamicGraphService, shared: &Shared) -> u64 {
    let cp = service.checkpoint();
    let seq = cp.seq;
    *shared.checkpoint_slot() = Some(cp);
    seq
}

fn maybe_checkpoint(service: &DynamicGraphService, shared: &Shared, every: u64) {
    let seq = service.update_seq();
    let due = match &*shared.checkpoint_slot() {
        None => true,
        Some(cp) => seq >= cp.seq + every.max(1),
    };
    if due {
        store_checkpoint(service, shared);
    }
}

/// Process requests until every handle is dropped. Expired mutating
/// requests are shed; successful updates refresh the shared checkpoint.
fn serve_loop(
    service: &mut DynamicGraphService,
    rx: &mpsc::Receiver<Envelope>,
    shared: &Shared,
    cfg: &ServerConfig,
) {
    while let Ok(env) = rx.recv() {
        let expired = env.deadline.is_some_and(|d| Instant::now() > d);
        match env.req {
            Request::Update(batch, resp) => {
                if expired {
                    let _ = resp.send(Err(ServerError::DeadlineExceeded));
                    continue;
                }
                let result = service
                    .apply_update(batch)
                    .map_err(|e| ServerError::Rejected(e.to_string()));
                let ok = result.is_ok();
                let _ = resp.send(result);
                if ok {
                    maybe_checkpoint(service, shared, cfg.checkpoint_every);
                }
            }
            Request::TopK(k, resp) => {
                let _ = resp.send(service.top_k(k));
            }
            Request::RanksOf(vs, resp) => {
                let ranks = service.ranks().unwrap_or(&[]);
                let out = vs
                    .iter()
                    .map(|&v| ranks.get(v as usize).copied().unwrap_or(0.0))
                    .collect();
                let _ = resp.send(out);
            }
            Request::Stats(resp) => {
                let _ = resp.send(service.metrics.summary());
            }
            Request::RefreshStatic(resp) => {
                if expired {
                    let _ = resp.send(Err(ServerError::DeadlineExceeded));
                    continue;
                }
                let result = service
                    .refresh_static()
                    .map_err(|e| ServerError::Rejected(e.to_string()));
                let ok = result.is_ok();
                let _ = resp.send(result);
                if ok {
                    maybe_checkpoint(service, shared, cfg.checkpoint_every);
                }
            }
            Request::Checkpoint(resp) => {
                let _ = resp.send(store_checkpoint(service, shared));
            }
        }
    }
}

/// Spawn the coordinator loop on a supervised thread; returns the handle.
/// The loop exits when every handle is dropped.
///
/// Takes a *factory* rather than a service: the PJRT client handles inside
/// [`crate::runtime::ArtifactStore`] are not `Send`, so the service (and
/// its store) must be constructed on the coordinator thread itself. If the
/// coordinator panics, the supervisor respawns it from the last checkpoint
/// (store-less: it serves from the native engines) — the factory is only
/// ever called once.
pub fn spawn<F>(make: F) -> CoordinatorHandle
where
    F: FnOnce() -> DynamicGraphService + Send + 'static,
{
    spawn_with(make, ServerConfig::default())
}

/// [`spawn`] with explicit front-end tunables.
pub fn spawn_with<F>(make: F, cfg: ServerConfig) -> CoordinatorHandle
where
    F: FnOnce() -> DynamicGraphService + Send + 'static,
{
    let (tx, rx) = mpsc::sync_channel::<Envelope>(cfg.queue_capacity.max(1));
    let shared = Arc::new(Shared::default());
    let handle = CoordinatorHandle {
        tx,
        shared: Arc::clone(&shared),
        capacity: cfg.queue_capacity.max(1),
    };
    std::thread::spawn(move || {
        let mut make = Some(make);
        loop {
            let make_once = make.take();
            let done = catch_unwind(AssertUnwindSafe(|| {
                let mut service = match make_once {
                    Some(f) => f(),
                    None => {
                        let cp = shared.checkpoint_slot().clone();
                        match cp.as_ref().map(|cp| DynamicGraphService::restore(cp, None))
                        {
                            Some(Ok(s)) => s,
                            // no checkpoint (or a poisoned one): nothing
                            // safe to resume from — shut down
                            _ => return true,
                        }
                    }
                };
                serve_loop(&mut service, &rx, &shared, &cfg);
                true
            }));
            match done {
                Ok(_) => break, // channel closed: clean shutdown
                Err(_) => {
                    let n = shared.respawns.fetch_add(1, Ordering::SeqCst) + 1;
                    if n > cfg.respawn_limit {
                        break; // dropping rx: handles observe Stopped
                    }
                }
            }
        }
    });
    handle
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batch::random_batch;
    use crate::engines::config::PagerankConfig;
    use crate::generators::er;

    #[test]
    fn serve_updates_and_queries() {
        let b = er::generate(200, 4.0, 1);
        let probe = random_batch(&b, 4, 0.8, 2);
        let h = spawn(move || DynamicGraphService::new(b, None, PagerankConfig::default()));

        let r0 = h.update(BatchUpdate::default()).unwrap();
        assert!(r0.iterations > 0);
        let r1 = h.update(probe).unwrap();
        assert!(r1.edges_changed > 0);

        let top = h.top_k(5).unwrap();
        assert_eq!(top.len(), 5);
        let ranks = h.ranks_of(vec![0, 1, 2]).unwrap();
        assert_eq!(ranks.len(), 3);
        assert!(ranks.iter().all(|&r| r > 0.0));
        let stats = h.stats().unwrap();
        assert!(stats.contains("updates=2"));
        assert!(stats.contains("watchdog_trips=0"), "{stats}");
    }

    #[test]
    fn concurrent_clients_serialize() {
        let h = spawn(|| {
            DynamicGraphService::new(er::generate(150, 4.0, 9), None, PagerankConfig::default())
        });
        h.update(BatchUpdate::default()).unwrap();

        std::thread::scope(|s| {
            for i in 0..8 {
                let h = h.clone();
                s.spawn(move || {
                    if i % 2 == 0 {
                        let top = h.top_k(3).unwrap();
                        assert_eq!(top.len(), 3);
                    } else {
                        let stats = h.stats().unwrap();
                        assert!(!stats.is_empty());
                    }
                });
            }
        });
    }

    #[test]
    fn handle_survives_refresh() {
        let h = spawn(|| {
            DynamicGraphService::new(er::generate(100, 4.0, 5), None, PagerankConfig::default())
        });
        h.update(BatchUpdate::default()).unwrap();
        let rep = h.refresh_static().unwrap();
        assert!(rep.iterations > 0);
    }

    #[test]
    fn checkpoints_accumulate_automatically() {
        let b = er::generate(120, 4.0, 2);
        let h = spawn_with(
            move || DynamicGraphService::new(b, None, PagerankConfig::default()),
            ServerConfig { checkpoint_every: 1, ..Default::default() },
        );
        assert!(h.last_checkpoint().is_none());
        h.update(BatchUpdate::default()).unwrap();
        let cp = h.last_checkpoint().expect("first update checkpoints");
        assert_eq!(cp.seq, 1);
        assert!(cp.ranks.is_some());
        let seq = h.checkpoint_now().unwrap();
        assert_eq!(seq, 1, "on-demand checkpoint at current seq");
    }

    #[test]
    fn zero_deadline_request_is_shed() {
        let h = spawn(|| {
            DynamicGraphService::new(er::generate(400, 4.0, 7), None, PagerankConfig::default())
        });
        h.update(BatchUpdate::default()).unwrap();
        // a deadline that has already passed when the coordinator dequeues
        // the request: shed server-side or timed out client-side
        let err = h
            .update_with_deadline(BatchUpdate::default(), Duration::ZERO)
            .unwrap_err();
        assert_eq!(err, ServerError::DeadlineExceeded);
        // the service is still healthy
        assert_eq!(h.top_k(3).unwrap().len(), 3);
    }
}
