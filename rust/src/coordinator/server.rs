//! Request front-end for the coordinator.
//!
//! The service is single-writer (it owns the evolving graph), so requests
//! are serialized through an mpsc channel into a dedicated thread (PJRT
//! execution is synchronous); clients get a cheap cloneable
//! [`CoordinatorHandle`]. This is the "leader" loop of the L3 architecture:
//! update producers and rank readers never touch the graph state directly.

use std::sync::mpsc;

use anyhow::{anyhow, Result};

use super::{DynamicGraphService, UpdateReport};
use crate::batch::BatchUpdate;
use crate::graph::VertexId;

enum Request {
    Update(BatchUpdate, mpsc::Sender<Result<UpdateReport>>),
    TopK(usize, mpsc::Sender<Vec<(VertexId, f64)>>),
    RanksOf(Vec<VertexId>, mpsc::Sender<Vec<f64>>),
    Stats(mpsc::Sender<String>),
    RefreshStatic(mpsc::Sender<Result<UpdateReport>>),
}

/// Cloneable handle to a running coordinator. Methods block until the
/// coordinator thread answers (requests are processed in FIFO order).
#[derive(Clone)]
pub struct CoordinatorHandle {
    tx: mpsc::Sender<Request>,
}

impl CoordinatorHandle {
    fn call<T>(&self, make: impl FnOnce(mpsc::Sender<T>) -> Request) -> Result<T> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(make(tx))
            .map_err(|_| anyhow!("coordinator stopped"))?;
        rx.recv().map_err(|_| anyhow!("coordinator dropped request"))
    }

    /// Apply a batch update; returns once ranks are refreshed.
    pub fn update(&self, batch: BatchUpdate) -> Result<UpdateReport> {
        self.call(|tx| Request::Update(batch, tx))?
    }

    /// Highest-ranked vertices.
    pub fn top_k(&self, k: usize) -> Result<Vec<(VertexId, f64)>> {
        self.call(|tx| Request::TopK(k, tx))
    }

    /// Ranks of specific vertices (0.0 if not yet computed).
    pub fn ranks_of(&self, vertices: Vec<VertexId>) -> Result<Vec<f64>> {
        self.call(|tx| Request::RanksOf(vertices, tx))
    }

    /// Metrics summary line.
    pub fn stats(&self) -> Result<String> {
        self.call(Request::Stats)
    }

    /// Force a full static refresh.
    pub fn refresh_static(&self) -> Result<UpdateReport> {
        self.call(Request::RefreshStatic)?
    }
}

/// Spawn the coordinator loop on a dedicated thread; returns the handle.
/// The loop exits when every handle is dropped.
///
/// Takes a *factory* rather than a service: the PJRT client handles inside
/// [`crate::runtime::ArtifactStore`] are not `Send`, so the service (and
/// its store) must be constructed on the coordinator thread itself.
pub fn spawn<F>(make: F) -> CoordinatorHandle
where
    F: FnOnce() -> DynamicGraphService + Send + 'static,
{
    let (tx, rx) = mpsc::channel::<Request>();
    std::thread::spawn(move || {
        let mut service = make();
        while let Ok(req) = rx.recv() {
            match req {
                Request::Update(batch, resp) => {
                    let _ = resp.send(service.apply_update(batch));
                }
                Request::TopK(k, resp) => {
                    let _ = resp.send(service.top_k(k));
                }
                Request::RanksOf(vs, resp) => {
                    let ranks = service.ranks().unwrap_or(&[]);
                    let out = vs
                        .iter()
                        .map(|&v| ranks.get(v as usize).copied().unwrap_or(0.0))
                        .collect();
                    let _ = resp.send(out);
                }
                Request::Stats(resp) => {
                    let _ = resp.send(service.metrics.summary());
                }
                Request::RefreshStatic(resp) => {
                    let _ = resp.send(service.refresh_static());
                }
            }
        }
    });
    CoordinatorHandle { tx }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batch::random_batch;
    use crate::engines::config::PagerankConfig;
    use crate::generators::er;

    #[test]
    fn serve_updates_and_queries() {
        let b = er::generate(200, 4.0, 1);
        let probe = random_batch(&b, 4, 0.8, 2);
        let h = spawn(move || DynamicGraphService::new(b, None, PagerankConfig::default()));

        let r0 = h.update(BatchUpdate::default()).unwrap();
        assert!(r0.iterations > 0);
        let r1 = h.update(probe).unwrap();
        assert!(r1.edges_changed > 0);

        let top = h.top_k(5).unwrap();
        assert_eq!(top.len(), 5);
        let ranks = h.ranks_of(vec![0, 1, 2]).unwrap();
        assert_eq!(ranks.len(), 3);
        assert!(ranks.iter().all(|&r| r > 0.0));
        let stats = h.stats().unwrap();
        assert!(stats.contains("updates=2"));
    }

    #[test]
    fn concurrent_clients_serialize() {
        let h = spawn(|| {
            DynamicGraphService::new(er::generate(150, 4.0, 9), None, PagerankConfig::default())
        });
        h.update(BatchUpdate::default()).unwrap();

        std::thread::scope(|s| {
            for i in 0..8 {
                let h = h.clone();
                s.spawn(move || {
                    if i % 2 == 0 {
                        let top = h.top_k(3).unwrap();
                        assert_eq!(top.len(), 3);
                    } else {
                        let stats = h.stats().unwrap();
                        assert!(!stats.is_empty());
                    }
                });
            }
        });
    }

    #[test]
    fn handle_survives_refresh() {
        let h = spawn(|| {
            DynamicGraphService::new(er::generate(100, 4.0, 5), None, PagerankConfig::default())
        });
        h.update(BatchUpdate::default()).unwrap();
        let rep = h.refresh_static().unwrap();
        assert!(rep.iterations > 0);
    }
}
