//! Serving metrics: per-approach latency/iterations accounting.

use std::collections::HashMap;
use std::time::Duration;

use crate::engines::Approach;

/// Aggregates for one approach.
#[derive(Debug, Clone, Default)]
pub struct ApproachStats {
    pub runs: usize,
    pub total_time: Duration,
    pub total_iterations: usize,
    pub max_time: Duration,
}

impl ApproachStats {
    fn record(&mut self, elapsed: Duration, iterations: usize) {
        self.runs += 1;
        self.total_time += elapsed;
        self.total_iterations += iterations;
        self.max_time = self.max_time.max(elapsed);
    }

    pub fn mean_time(&self) -> Duration {
        if self.runs == 0 {
            Duration::ZERO
        } else {
            self.total_time / self.runs as u32
        }
    }
}

/// Coordinator-wide counters: throughput plus the robustness-layer health
/// signals (quarantined edits, watchdog trips, recoveries, restores).
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    pub updates_applied: usize,
    pub edges_inserted: usize,
    pub edges_deleted: usize,
    pub device_runs: usize,
    pub native_fallbacks: usize,
    /// Edits rejected by `batch::validate` instead of applied.
    pub quarantined_edits: usize,
    /// Engine results the rank-health watchdog refused to install.
    pub watchdog_trips: usize,
    /// Updates that succeeded only after escalating the degradation ladder.
    pub health_recoveries: usize,
    /// Times this service was rebuilt from a checkpoint.
    pub restores: usize,
    /// Total nanoseconds spent maintaining the graph structures per update
    /// (batch validation/apply, CSR maintenance or rebuild + transpose,
    /// previous-snapshot bookkeeping) — everything outside the engine run.
    pub maintenance_ns: u64,
    pub per_approach: HashMap<Approach, ApproachStats>,
}

impl Metrics {
    pub fn record_update(&mut self, inserted: usize, deleted: usize) {
        self.updates_applied += 1;
        self.edges_inserted += inserted;
        self.edges_deleted += deleted;
    }

    pub fn record_quarantined(&mut self, edits: usize) {
        self.quarantined_edits += edits;
    }

    pub fn record_watchdog_trip(&mut self) {
        self.watchdog_trips += 1;
    }

    pub fn record_recovery(&mut self) {
        self.health_recoveries += 1;
    }

    pub fn record_restore(&mut self) {
        self.restores += 1;
    }

    pub fn record_maintenance(&mut self, d: Duration) {
        self.maintenance_ns = self.maintenance_ns.saturating_add(d.as_nanos() as u64);
    }

    pub fn record_run(
        &mut self,
        approach: Approach,
        elapsed: Duration,
        iterations: usize,
        on_device: bool,
    ) {
        if on_device {
            self.device_runs += 1;
        } else {
            self.native_fallbacks += 1;
        }
        self.per_approach.entry(approach).or_default().record(elapsed, iterations);
    }

    /// One-line summary for logs: throughput, then health, then
    /// per-approach latency.
    pub fn summary(&self) -> String {
        let mut parts = vec![format!(
            "updates={} (+{} -{}) device_runs={} native_fallbacks={}",
            self.updates_applied,
            self.edges_inserted,
            self.edges_deleted,
            self.device_runs,
            self.native_fallbacks
        )];
        parts.push(format!(
            "health: quarantined={} watchdog_trips={} recoveries={} restores={}",
            self.quarantined_edits,
            self.watchdog_trips,
            self.health_recoveries,
            self.restores
        ));
        parts.push(format!(
            "maintenance: {:.2?}",
            Duration::from_nanos(self.maintenance_ns)
        ));
        let mut keys: Vec<_> = self.per_approach.keys().copied().collect();
        keys.sort_by_key(|a| a.label());
        for a in keys {
            let s = &self.per_approach[&a];
            parts.push(format!(
                "{}: {} runs, mean {:.2?}, {} iters",
                a.label(),
                s.runs,
                s.mean_time(),
                s.total_iterations
            ));
        }
        parts.join(" | ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let mut m = Metrics::default();
        m.record_update(8, 2);
        m.record_run(Approach::Static, Duration::from_millis(10), 50, true);
        m.record_run(Approach::DynamicFrontierPruning, Duration::from_millis(2), 5, true);
        m.record_run(Approach::DynamicFrontierPruning, Duration::from_millis(4), 7, false);
        assert_eq!(m.updates_applied, 1);
        assert_eq!(m.device_runs, 2);
        assert_eq!(m.native_fallbacks, 1);
        let s = &m.per_approach[&Approach::DynamicFrontierPruning];
        assert_eq!(s.runs, 2);
        assert_eq!(s.mean_time(), Duration::from_millis(3));
        assert!(m.summary().contains("DF-P"));
    }

    #[test]
    fn summary_surfaces_health_counters() {
        let mut m = Metrics::default();
        m.record_quarantined(4);
        m.record_watchdog_trip();
        m.record_watchdog_trip();
        m.record_recovery();
        m.record_restore();
        assert_eq!(m.quarantined_edits, 4);
        assert_eq!(m.watchdog_trips, 2);
        let s = m.summary();
        assert!(s.contains("quarantined=4"), "{s}");
        assert!(s.contains("watchdog_trips=2"), "{s}");
        assert!(s.contains("recoveries=1"), "{s}");
        assert!(s.contains("restores=1"), "{s}");
    }

    #[test]
    fn maintenance_accumulates_and_shows_in_summary() {
        let mut m = Metrics::default();
        m.record_maintenance(Duration::from_micros(300));
        m.record_maintenance(Duration::from_micros(700));
        assert_eq!(m.maintenance_ns, 1_000_000);
        assert!(m.summary().contains("maintenance:"), "{}", m.summary());
        m.maintenance_ns = u64::MAX - 10;
        m.record_maintenance(Duration::from_secs(1));
        assert_eq!(m.maintenance_ns, u64::MAX, "saturates, never wraps");
    }
}
