//! Deterministic fault injection for the robustness suite.
//!
//! A [`FaultPlan`] schedules faults against the coordinator's update
//! sequence numbers: poisoning ranks with NaNs, forcing an iteration-cap
//! stall, appending malformed edits to an incoming batch, or killing the
//! coordinator thread mid-stream. Everything is derived from a seed via
//! [`crate::util::Rng`], so a failing run replays bit-for-bit.
//!
//! The plan is armed on a service with
//! [`super::DynamicGraphService::arm_faults`]; each scheduled fault fires
//! exactly once, at the start (kill / malformed batch) or engine boundary
//! (corruption / stall) of the matching `apply_update` call. The tests in
//! `tests/robustness.rs` assert that every fault is detected by the
//! validation pass, the watchdog or the supervisor — and that the service
//! recovers.

use std::collections::BTreeMap;

use crate::batch::BatchUpdate;
use crate::graph::VertexId;
use crate::util::Rng;

/// One injectable fault.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    /// Overwrite `nans` randomly-chosen ranks with NaN after the engine run
    /// (models a device memory fault / kernel bug).
    CorruptRanks { nans: usize },
    /// Report the run as having hit the iteration cap (models
    /// non-convergence on a pathological graph).
    Stall,
    /// Append `edits` malformed edits (out-of-range ids, phantom deletions,
    /// self-loops) to the incoming batch (models a buggy or hostile client).
    MalformedBatch { edits: usize },
    /// Panic inside `apply_update` (models a wedged/crashed coordinator;
    /// the server supervisor must respawn from the last checkpoint).
    KillCoordinator,
    /// Submit a parallel region with a panicking chunk to the persistent
    /// worker pool (models a bug inside engine code running on the pool).
    /// The pool itself survives — per-task unwind catching turns this into
    /// a typed `par::PoolPanic` on the coordinator thread — so what the
    /// suite asserts is that the *coordinator* crash is supervised and
    /// respawned, and that the pool keeps serving afterwards.
    PoisonPool,
}

impl Fault {
    pub fn label(&self) -> &'static str {
        match self {
            Fault::CorruptRanks { .. } => "corrupt-ranks",
            Fault::Stall => "stall",
            Fault::MalformedBatch { .. } => "malformed-batch",
            Fault::KillCoordinator => "kill-coordinator",
            Fault::PoisonPool => "poison-pool",
        }
    }
}

/// A seeded schedule of faults keyed by update sequence number.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    seed: u64,
    schedule: BTreeMap<u64, Fault>,
}

impl FaultPlan {
    pub fn new(seed: u64) -> Self {
        Self { seed, schedule: BTreeMap::new() }
    }

    /// Schedule `fault` to fire on the `update_seq`-th `apply_update` call
    /// (0-based; the initial static computation is seq 0).
    pub fn at(mut self, update_seq: u64, fault: Fault) -> Self {
        self.schedule.insert(update_seq, fault);
        self
    }

    /// Faults not yet fired.
    pub fn pending(&self) -> usize {
        self.schedule.len()
    }

    /// Remove and return the fault scheduled for `seq`, if any.
    pub fn take(&mut self, seq: u64) -> Option<Fault> {
        self.schedule.remove(&seq)
    }

    /// Per-(seed, seq) RNG so each fault's randomness is reproducible
    /// regardless of what fired before it.
    fn rng(&self, seq: u64) -> Rng {
        Rng::seed_from_u64(self.seed ^ seq.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Poison `nans` distinct positions of `ranks` with NaN.
    pub fn corrupt_ranks(&self, seq: u64, nans: usize, ranks: &mut [f64]) {
        if ranks.is_empty() {
            return;
        }
        let mut rng = self.rng(seq);
        for i in rng.sample_indices(ranks.len(), nans.max(1)) {
            ranks[i] = f64::NAN;
        }
    }

    /// Deterministic malformed edits against a graph of `num_vertices`
    /// vertices: cycles through out-of-range insertions, phantom deletions
    /// of a (hopefully absent) far-apart pair, and self-loop edits.
    pub fn malformed_edits(&self, seq: u64, num_vertices: usize, edits: usize) -> BatchUpdate {
        let mut rng = self.rng(seq);
        let n = num_vertices as u64;
        let mut b = BatchUpdate::default();
        for i in 0..edits {
            match i % 3 {
                0 => {
                    // out of range: id in [n, 2n)
                    let u = rng.gen_range_u64(n, 2 * n.max(1)) as VertexId;
                    let v = rng.gen_range_u64(0, n.max(1)) as VertexId;
                    b.insertions.push((u, v));
                }
                1 => {
                    // phantom deletion (validated against the live graph;
                    // classified out-of-range if n < 2)
                    let u = rng.gen_range_u64(0, n.max(1)) as VertexId;
                    b.deletions.push((u, u.wrapping_add(1) % n.max(1) as VertexId));
                }
                _ => {
                    let u = rng.gen_range_u64(0, n.max(1)) as VertexId;
                    b.insertions.push((u, u)); // self-loop
                }
            }
        }
        b
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_fires_once() {
        let mut p = FaultPlan::new(1)
            .at(2, Fault::Stall)
            .at(5, Fault::KillCoordinator);
        assert_eq!(p.pending(), 2);
        assert_eq!(p.take(0), None);
        assert_eq!(p.take(2), Some(Fault::Stall));
        assert_eq!(p.take(2), None, "consumed");
        assert_eq!(p.take(5), Some(Fault::KillCoordinator));
        assert_eq!(p.pending(), 0);
    }

    #[test]
    fn corruption_is_deterministic_per_seed_and_seq() {
        let p = FaultPlan::new(42);
        let mut a = vec![0.1; 50];
        let mut b = vec![0.1; 50];
        p.corrupt_ranks(3, 5, &mut a);
        p.corrupt_ranks(3, 5, &mut b);
        let nan_at = |r: &[f64]| -> Vec<usize> {
            r.iter().enumerate().filter(|(_, x)| x.is_nan()).map(|(i, _)| i).collect()
        };
        assert_eq!(nan_at(&a), nan_at(&b));
        assert_eq!(nan_at(&a).len(), 5);
        let mut c = vec![0.1; 50];
        p.corrupt_ranks(4, 5, &mut c);
        assert_ne!(nan_at(&a), nan_at(&c), "different seq, different positions");
    }

    #[test]
    fn malformed_edits_are_actually_malformed() {
        let p = FaultPlan::new(9);
        let b = p.malformed_edits(1, 100, 9);
        assert_eq!(b.len(), 9);
        let out_of_range = b
            .insertions
            .iter()
            .filter(|&&(u, _)| u >= 100)
            .count();
        let self_loops = b.insertions.iter().filter(|&&(u, v)| u == v && u < 100).count();
        assert!(out_of_range >= 3, "{b:?}");
        assert!(self_loops >= 3, "{b:?}");
        assert_eq!(b.deletions.len(), 3);
        // deterministic
        assert_eq!(p.malformed_edits(1, 100, 9), b);
    }
}
