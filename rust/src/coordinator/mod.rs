//! The dynamic-graph coordinator: owns the evolving graph and its PageRank
//! state, applies batch updates, chooses the update approach (policy), and
//! dispatches to the device (artifact) or native engine.
//!
//! This is the L3 "serving" layer: Python never runs here — the device path
//! executes pre-compiled HLO artifacts via PJRT.

pub mod metrics;
pub mod policy;
pub mod server;

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::batch::{self, BatchUpdate};
use crate::engines::config::PagerankConfig;
use crate::engines::device::DeviceEngine;
use crate::engines::{native, Approach, PagerankResult};
use crate::graph::{CsrGraph, GraphBuilder, VertexId};
use crate::runtime::ArtifactStore;

pub use metrics::Metrics;
pub use policy::{ApproachPolicy, PolicyConfig};

/// What happened when a batch was applied.
#[derive(Debug, Clone)]
pub struct UpdateReport {
    pub approach: Approach,
    pub on_device: bool,
    pub iterations: usize,
    pub elapsed: Duration,
    pub initially_affected: usize,
    pub num_vertices: usize,
    pub num_edges: usize,
    pub edges_changed: usize,
}

/// The coordinator service. Single-writer: wrap in the [`server`] loop for
/// concurrent access.
pub struct DynamicGraphService {
    builder: GraphBuilder,
    /// CSR of the previous snapshot (DT marks reachability in old ∪ new).
    prev_csr: CsrGraph,
    ranks: Option<Vec<f64>>,
    store: Option<Arc<ArtifactStore>>,
    pub cfg: PagerankConfig,
    pub policy: ApproachPolicy,
    pub metrics: Metrics,
}

impl DynamicGraphService {
    /// Create from an initial graph. `store` enables the device engine
    /// (falls back to native for graphs beyond the largest tier).
    pub fn new(
        mut builder: GraphBuilder,
        store: Option<Arc<ArtifactStore>>,
        cfg: PagerankConfig,
    ) -> Self {
        builder.ensure_self_loops();
        let prev_csr = builder.to_csr();
        Self {
            builder,
            prev_csr,
            ranks: None,
            store,
            cfg,
            policy: ApproachPolicy::default(),
            metrics: Metrics::default(),
        }
    }

    pub fn num_vertices(&self) -> usize {
        self.builder.num_vertices()
    }

    pub fn num_edges(&self) -> usize {
        self.builder.num_edges()
    }

    pub fn ranks(&self) -> Option<&[f64]> {
        self.ranks.as_deref()
    }

    /// Top-k vertices by rank (requires at least one computation).
    pub fn top_k(&self, k: usize) -> Vec<(VertexId, f64)> {
        let Some(r) = &self.ranks else { return Vec::new() };
        let mut idx: Vec<VertexId> = (0..r.len() as VertexId).collect();
        idx.sort_unstable_by(|&a, &b| {
            r[b as usize].partial_cmp(&r[a as usize]).unwrap()
        });
        idx.into_iter().take(k).map(|v| (v, r[v as usize])).collect()
    }

    /// Run one approach against the current graph, preferring the device
    /// engine when the graph fits a tier.
    fn run(
        &self,
        approach: Approach,
        g: &CsrGraph,
        gt: &CsrGraph,
        batch: &BatchUpdate,
    ) -> Result<(PagerankResult, bool)> {
        let prev = self.ranks.as_deref();
        if let Some(store) = &self.store {
            if store.tier_for(g.num_vertices(), g.num_edges()).is_some() {
                let dg = store.pack_graph(g, gt)?;
                let eng = DeviceEngine::new(store);
                let res = eng.run_approach(
                    approach,
                    &dg,
                    g,
                    &self.prev_csr,
                    &self.cfg,
                    prev,
                    batch,
                )?;
                return Ok((res, true));
            }
        }
        let res = match approach {
            Approach::Static => native::static_pagerank(g, gt, &self.cfg, None),
            Approach::NaiveDynamic => {
                native::naive_dynamic(g, gt, &self.cfg, prev.expect("ND needs ranks"))
            }
            Approach::DynamicTraversal => native::dynamic::dynamic_traversal(
                g,
                gt,
                &self.prev_csr,
                &self.cfg,
                prev.expect("DT needs ranks"),
                batch,
            ),
            Approach::DynamicFrontier => native::dynamic::dynamic_frontier(
                g,
                gt,
                &self.cfg,
                prev.expect("DF needs ranks"),
                batch,
                false,
            ),
            Approach::DynamicFrontierPruning => native::dynamic::dynamic_frontier(
                g,
                gt,
                &self.cfg,
                prev.expect("DF-P needs ranks"),
                batch,
                true,
            ),
        };
        Ok((res, false))
    }

    /// Compute the initial ranks (Static) if none exist yet.
    pub fn ensure_ranks(&mut self) -> Result<UpdateReport> {
        if self.ranks.is_some() {
            let g = self.builder.to_csr();
            return Ok(UpdateReport {
                approach: Approach::Static,
                on_device: false,
                iterations: 0,
                elapsed: Duration::ZERO,
                initially_affected: 0,
                num_vertices: g.num_vertices(),
                num_edges: g.num_edges(),
                edges_changed: 0,
            });
        }
        self.apply_update(BatchUpdate::default())
    }

    /// Apply a batch update and refresh ranks with the policy-chosen
    /// approach. An empty batch on a fresh service triggers the initial
    /// Static computation.
    pub fn apply_update(&mut self, batch: BatchUpdate) -> Result<UpdateReport> {
        let old_csr = self.builder.to_csr();
        let edges_changed = batch::apply(&mut self.builder, &batch);
        let g = self.builder.to_csr();
        let gt = g.transpose();

        let approach =
            self.policy.choose(batch.len(), g.num_edges(), self.ranks.is_some());
        let (res, on_device) = self.run(approach, &g, &gt, &batch)?;

        self.metrics.record_update(batch.insertions.len(), batch.deletions.len());
        self.metrics.record_run(approach, res.elapsed, res.iterations, on_device);

        let report = UpdateReport {
            approach,
            on_device,
            iterations: res.iterations,
            elapsed: res.elapsed,
            initially_affected: res.initially_affected,
            num_vertices: g.num_vertices(),
            num_edges: g.num_edges(),
            edges_changed,
        };
        self.ranks = Some(res.ranks);
        self.prev_csr = old_csr;
        Ok(report)
    }

    /// Force a full static recomputation (periodic refresh; also resets the
    /// policy's error guard).
    pub fn refresh_static(&mut self) -> Result<UpdateReport> {
        let g = self.builder.to_csr();
        let gt = g.transpose();
        let (res, on_device) = self.run(Approach::Static, &g, &gt, &BatchUpdate::default())?;
        self.metrics
            .record_run(Approach::Static, res.elapsed, res.iterations, on_device);
        self.policy.reset();
        let report = UpdateReport {
            approach: Approach::Static,
            on_device,
            iterations: res.iterations,
            elapsed: res.elapsed,
            initially_affected: 0,
            num_vertices: g.num_vertices(),
            num_edges: g.num_edges(),
            edges_changed: 0,
        };
        self.ranks = Some(res.ranks);
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generators::er;

    fn service(n: usize) -> DynamicGraphService {
        DynamicGraphService::new(
            er::generate(n, 4.0, 3),
            None, // native-only in unit tests; device covered in tests/
            PagerankConfig::default(),
        )
    }

    #[test]
    fn first_update_is_static_then_dfp() {
        // policy switches to ND above 1e-4|E|, so use a 1-edge batch on a
        // graph with >10k edges to stay in DF-P territory
        let mut s = service(3000);
        let r0 = s.apply_update(BatchUpdate::default()).unwrap();
        assert_eq!(r0.approach, Approach::Static);
        assert!(s.ranks().is_some());

        let b = batch::random_batch(&s.builder, 1, 1.0, 1);
        let r1 = s.apply_update(b).unwrap();
        assert_eq!(r1.approach, Approach::DynamicFrontierPruning);
        assert!(r1.initially_affected > 0);
    }

    #[test]
    fn large_batch_switches_to_nd() {
        let mut s = service(300);
        s.ensure_ranks().unwrap();
        let m = s.num_edges();
        let b = batch::random_batch(&s.builder, m / 100, 0.8, 2); // 1% >> 1e-4
        let r = s.apply_update(b).unwrap();
        assert_eq!(r.approach, Approach::NaiveDynamic);
    }

    #[test]
    fn top_k_sorted() {
        let mut s = service(200);
        s.ensure_ranks().unwrap();
        let top = s.top_k(10);
        assert_eq!(top.len(), 10);
        assert!(top.windows(2).all(|w| w[0].1 >= w[1].1));
    }

    #[test]
    fn ranks_stay_close_to_static_across_updates() {
        let mut s = service(250);
        s.ensure_ranks().unwrap();
        for seed in 0..5 {
            let b = batch::random_batch(&s.builder, 3, 0.8, seed);
            s.apply_update(b).unwrap();
        }
        let g = s.builder.to_csr();
        let gt = g.transpose();
        let want = native::static_pagerank(&g, &gt, &s.cfg, None).ranks;
        let err: f64 = s
            .ranks()
            .unwrap()
            .iter()
            .zip(&want)
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(err < 1e-2, "accumulated L1 error {err}");
    }

    #[test]
    fn metrics_accumulate() {
        let mut s = service(150);
        s.ensure_ranks().unwrap();
        let b = batch::random_batch(&s.builder, 2, 0.8, 7);
        s.apply_update(b).unwrap();
        assert_eq!(s.metrics.updates_applied, 2);
        assert!(s.metrics.summary().contains("Static"));
    }
}
