//! The dynamic-graph coordinator: owns the evolving graph and its PageRank
//! state, applies batch updates, chooses the update approach (policy), and
//! dispatches to the device (artifact) or native engine.
//!
//! This is the L3 "serving" layer: Python never runs here — the device path
//! executes pre-compiled HLO artifacts via PJRT.
//!
//! # Robustness layer
//!
//! The coordinator trusts nothing it is handed:
//!
//! * **Validated ingestion** — every batch goes through
//!   [`crate::batch::validate`] first; malformed edits (out-of-range ids,
//!   duplicate insertions, phantom deletions, self-loops) are quarantined
//!   and reported in the [`UpdateReport`], the clean subset is applied.
//! * **Rank-health watchdog** — every engine result is checked
//!   ([`health::check_ranks`]) for NaN/Inf/negative ranks, rank-mass drift
//!   and iteration-cap stalls before it is installed. A bad result is never
//!   served: the coordinator escalates the degradation ladder
//!   (DF-P → ND → full Static, [`ApproachPolicy::escalate`]) within the
//!   same update and keeps the last-known-good ranks until a healthy
//!   result lands.
//! * **Checkpoint/restore** — [`DynamicGraphService::checkpoint`] snapshots
//!   (edge list, ranks, metrics, config); [`DynamicGraphService::restore`]
//!   rebuilds a warm service from it (the [`server`] supervisor uses this
//!   to respawn a panicked coordinator thread).
//! * **Fault injection** — a seeded [`FaultPlan`] drives the deterministic
//!   robustness suite (`tests/robustness.rs`).
//!
//! No public method of this type panics, even on poisoned inputs.

pub mod checkpoint;
pub mod faults;
pub mod health;
pub mod metrics;
pub mod policy;
pub mod server;

use std::collections::HashSet;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::batch::{self, BatchUpdate, Rejection};
use crate::engines::config::PagerankConfig;
use crate::engines::device::DeviceEngine;
use crate::engines::{native, Approach, PagerankResult};
use crate::graph::{CsrGraph, DynCsr, GraphBuilder, VertexId};
use crate::runtime::ArtifactStore;
use crate::util::par;

pub use checkpoint::Checkpoint;
pub use faults::{Fault, FaultPlan};
pub use health::{HealthConfig, HealthError, HealthViolation};
pub use metrics::Metrics;
pub use policy::{ApproachPolicy, HealthState, PolicyConfig};

/// What happened when a batch was applied.
#[derive(Debug, Clone)]
pub struct UpdateReport {
    pub approach: Approach,
    pub on_device: bool,
    pub iterations: usize,
    pub elapsed: Duration,
    pub initially_affected: usize,
    pub num_vertices: usize,
    pub num_edges: usize,
    pub edges_changed: usize,
    /// Edits rejected by validation instead of applied.
    pub quarantined: usize,
    /// The per-edit quarantine diagnoses.
    pub rejections: Vec<Rejection>,
    /// Engine results the watchdog rejected while serving this update.
    pub watchdog_trips: usize,
    /// Whether the policy is in degraded (conservative) mode after this
    /// update.
    pub degraded: bool,
    /// Time spent on graph maintenance (batch apply + CSR/transpose upkeep
    /// + prev-snapshot bookkeeping), separate from `elapsed` (engine time).
    /// In incremental CSR mode this is O(batch); in rebuild mode O(N + E).
    pub maintenance: Duration,
}

/// The coordinator service. Single-writer: wrap in the [`server`] loop for
/// concurrent access.
pub struct DynamicGraphService {
    builder: GraphBuilder,
    /// Incrementally-maintained G/Gᵀ (`graph::dyncsr`): `Some` in
    /// incremental CSR mode, kept in lockstep with `builder` by
    /// `apply_update`; `None` in rebuild mode (legacy per-update
    /// `to_csr()` + `transpose()`).
    dyn_graph: Option<DynCsr>,
    /// Edge delta from the current builder back to the *previous* snapshot
    /// (the graph DT marks old-side reachability in):
    /// `prev = current − prev_missing + prev_extra`. O(batch) to maintain;
    /// the CSR is materialized only when DT actually runs.
    prev_missing: HashSet<(VertexId, VertexId)>,
    prev_extra: HashSet<(VertexId, VertexId)>,
    ranks: Option<Vec<f64>>,
    store: Option<Arc<ArtifactStore>>,
    pub cfg: PagerankConfig,
    pub policy: ApproachPolicy,
    pub metrics: Metrics,
    /// Watchdog thresholds.
    pub health: HealthConfig,
    faults: Option<FaultPlan>,
    update_seq: u64,
}

impl DynamicGraphService {
    /// Create from an initial graph. `store` enables the device engine
    /// (falls back to native for graphs beyond the largest tier). The
    /// config is sanitized ([`PagerankConfig::sanitized`]) so an invalid
    /// field can never wedge or crash an engine run.
    pub fn new(
        mut builder: GraphBuilder,
        store: Option<Arc<ArtifactStore>>,
        cfg: PagerankConfig,
    ) -> Self {
        builder.ensure_self_loops();
        let cfg = cfg.sanitized();
        let dyn_graph =
            cfg.csr_mode.resolve_incremental().then(|| DynCsr::from_builder(&builder));
        Self {
            builder,
            dyn_graph,
            prev_missing: HashSet::new(),
            prev_extra: HashSet::new(),
            ranks: None,
            store,
            cfg,
            policy: ApproachPolicy::default(),
            metrics: Metrics::default(),
            health: HealthConfig::default(),
            faults: None,
            update_seq: 0,
        }
    }

    /// Rebuild a warm service from a checkpoint (edge list, ranks, metrics,
    /// config). The checkpoint is validated first: a poisoned snapshot is a
    /// typed error, not a corrupted service. `store` may be `None` — a
    /// supervisor respawning after a panic serves from the native engines
    /// until a store can be re-attached.
    pub fn restore(cp: &Checkpoint, store: Option<Arc<ArtifactStore>>) -> Result<Self> {
        cp.validate()?;
        let mut builder = GraphBuilder::new(cp.num_vertices);
        for &(u, v) in &cp.edges {
            builder.insert_edge(u, v);
        }
        builder.ensure_self_loops();
        // Re-seed the *previous*-snapshot delta from the checkpoint so
        // Dynamic Traversal (which BFS-marks over old ∪ new) stays exact
        // across a restore instead of silently seeing old == new. Any
        // self-loops `ensure_self_loops` added beyond `cp.edges` (possible
        // only in hand-crafted checkpoints) are new relative to the
        // previous snapshot, so they join `prev_missing`.
        let mut prev_missing: HashSet<(VertexId, VertexId)> =
            cp.prev_missing.iter().copied().collect();
        let cp_set: HashSet<(VertexId, VertexId)> = cp.edges.iter().copied().collect();
        for e in builder.edges() {
            if !cp_set.contains(&e) {
                prev_missing.insert(e);
            }
        }
        let cfg = cp.cfg.sanitized();
        let dyn_graph =
            cfg.csr_mode.resolve_incremental().then(|| DynCsr::from_builder(&builder));
        let mut metrics = cp.metrics.clone();
        metrics.record_restore();
        Ok(Self {
            builder,
            dyn_graph,
            prev_missing,
            prev_extra: cp.prev_extra.iter().copied().collect(),
            ranks: cp.ranks.clone(),
            store,
            cfg,
            policy: ApproachPolicy::default(),
            metrics,
            health: HealthConfig::default(),
            faults: None,
            update_seq: cp.seq,
        })
    }

    /// Snapshot the current state for later [`restore`](Self::restore).
    /// Alongside the current edge list this records the delta to the
    /// previous snapshot (`prev_missing` / `prev_extra`), so a restored
    /// service reconstructs the previous snapshot exactly and DT keeps its old-graph
    /// reachability after a respawn.
    pub fn checkpoint(&self) -> Checkpoint {
        let edges: Vec<(VertexId, VertexId)> = self.builder.edges().collect();
        // The delta is maintained directly (O(batch)), not recomputed by an
        // O(E) set diff per capture; sorted for a canonical snapshot.
        let mut prev_missing: Vec<_> = self.prev_missing.iter().copied().collect();
        let mut prev_extra: Vec<_> = self.prev_extra.iter().copied().collect();
        prev_missing.sort_unstable();
        prev_extra.sort_unstable();
        Checkpoint {
            seq: self.update_seq,
            num_vertices: self.builder.num_vertices(),
            edges,
            prev_missing,
            prev_extra,
            ranks: self.ranks.clone(),
            cfg: self.cfg,
            metrics: self.metrics.clone(),
        }
    }

    /// Arm a deterministic fault-injection plan (robustness tests).
    pub fn arm_faults(&mut self, plan: FaultPlan) {
        self.faults = Some(plan);
    }

    /// Monotone count of `apply_update` calls (checkpoint sequence).
    pub fn update_seq(&self) -> u64 {
        self.update_seq
    }

    /// Whether the watchdog has degraded the policy to conservative mode.
    pub fn degraded(&self) -> bool {
        self.policy.health() == HealthState::Degraded
    }

    pub fn num_vertices(&self) -> usize {
        self.builder.num_vertices()
    }

    pub fn num_edges(&self) -> usize {
        self.builder.num_edges()
    }

    pub fn ranks(&self) -> Option<&[f64]> {
        self.ranks.as_deref()
    }

    /// Top-k vertices by rank (requires at least one computation).
    /// Total-order comparison: a poisoned rank vector can never panic the
    /// read path (NaNs sort ahead of finite ranks, which the watchdog
    /// prevents from being installed in the first place).
    pub fn top_k(&self, k: usize) -> Vec<(VertexId, f64)> {
        let Some(r) = &self.ranks else { return Vec::new() };
        let mut idx: Vec<VertexId> = (0..r.len() as VertexId).collect();
        idx.sort_unstable_by(|&a, &b| {
            r[b as usize].total_cmp(&r[a as usize])
        });
        idx.into_iter().take(k).map(|v| (v, r[v as usize])).collect()
    }

    /// Fold the applied clean batch into the previous-snapshot delta,
    /// keeping `prev = current − prev_missing + prev_extra` pointing at the
    /// same graph it pointed at before the batch. Every clean edit is
    /// guaranteed applied ([`batch::validate`]), so parity is exact.
    fn absorb_prev_delta(&mut self, clean: &BatchUpdate) {
        for &e in &clean.deletions {
            // prev still has e unless it only existed since the snapshot
            if !self.prev_missing.remove(&e) {
                self.prev_extra.insert(e);
            }
        }
        for &e in &clean.insertions {
            // prev lacks e unless it had it before a post-snapshot deletion
            if !self.prev_extra.remove(&e) {
                self.prev_missing.insert(e);
            }
        }
    }

    /// Materialize the previous-snapshot CSR from the maintained delta —
    /// O(E log E), paid only when Dynamic Traversal actually needs the old
    /// graph (never on the DF-P/DF/ND/Static paths).
    fn materialize_prev(&self) -> CsrGraph {
        let mut edges: Vec<(VertexId, VertexId)> = self
            .builder
            .edges()
            .filter(|e| !self.prev_missing.contains(e))
            .chain(self.prev_extra.iter().copied())
            .collect();
        edges.sort_unstable();
        edges.dedup();
        CsrGraph::from_edges(self.builder.num_vertices(), &edges)
    }

    /// Run one approach against the current graph, preferring the device
    /// engine when the graph fits a tier. `prev_graph` is the previous
    /// snapshot — required by (and only by) Dynamic Traversal.
    fn run(
        &self,
        approach: Approach,
        g: &CsrGraph,
        gt: &CsrGraph,
        prev_graph: Option<&CsrGraph>,
        batch: &BatchUpdate,
    ) -> Result<(PagerankResult, bool)> {
        let prev = self.ranks.as_deref();
        let need_prev = |label: &str| {
            prev.ok_or_else(|| anyhow!("{label} requires previous ranks"))
        };
        let old_graph = |label: &str| {
            prev_graph.ok_or_else(|| anyhow!("{label} requires the previous graph snapshot"))
        };
        if let Some(store) = &self.store {
            if store.tier_for(g.num_vertices(), g.num_edges()).is_some() {
                let dg = store.pack_graph(g, gt)?;
                let eng = DeviceEngine::new(store);
                // Only the DT arm reads the old graph; every other approach
                // gets the current graph as a placeholder it never touches.
                let g_old = match approach {
                    Approach::DynamicTraversal => old_graph("device DT")?,
                    _ => g,
                };
                let res = eng.run_approach(
                    approach,
                    &dg,
                    g,
                    g_old,
                    &self.cfg,
                    prev,
                    batch,
                )?;
                return Ok((res, true));
            }
        }
        let res = match approach {
            Approach::Static => native::static_pagerank(g, gt, &self.cfg, None),
            Approach::NaiveDynamic => {
                native::naive_dynamic(g, gt, &self.cfg, need_prev("ND")?)
            }
            Approach::DynamicTraversal => native::dynamic::dynamic_traversal(
                g,
                gt,
                old_graph("DT")?,
                &self.cfg,
                need_prev("DT")?,
                batch,
            ),
            Approach::DynamicFrontier => native::dynamic::dynamic_frontier(
                g,
                gt,
                &self.cfg,
                need_prev("DF")?,
                batch,
                false,
            ),
            Approach::DynamicFrontierPruning => native::dynamic::dynamic_frontier(
                g,
                gt,
                &self.cfg,
                need_prev("DF-P")?,
                batch,
                true,
            ),
        };
        Ok((res, false))
    }

    /// Compute the initial ranks (Static) if none exist yet.
    pub fn ensure_ranks(&mut self) -> Result<UpdateReport> {
        if self.ranks.is_some() {
            // Counts come straight from the builder — no CSR rebuild for a
            // report-only fast path.
            return Ok(UpdateReport {
                approach: Approach::Static,
                on_device: false,
                iterations: 0,
                elapsed: Duration::ZERO,
                initially_affected: 0,
                num_vertices: self.builder.num_vertices(),
                num_edges: self.builder.num_edges(),
                edges_changed: 0,
                quarantined: 0,
                rejections: Vec::new(),
                watchdog_trips: 0,
                degraded: self.degraded(),
                maintenance: Duration::ZERO,
            });
        }
        self.apply_update(BatchUpdate::default())
    }

    /// Apply a batch update and refresh ranks with the policy-chosen
    /// approach. An empty batch on a fresh service triggers the initial
    /// Static computation.
    ///
    /// The batch is validated first (malformed edits quarantined, clean
    /// subset applied) and the resulting ranks are health-checked before
    /// installation; on a watchdog trip the degradation ladder re-runs with
    /// a more conservative approach. On any error the last-known-good ranks
    /// stay installed and keep being served.
    pub fn apply_update(&mut self, batch: BatchUpdate) -> Result<UpdateReport> {
        self.apply_update_impl(batch, None)
    }

    /// Like [`apply_update`](Self::apply_update), but with a caller-chosen
    /// approach instead of the policy's pick. The policy never selects
    /// Dynamic Traversal on its own (DF-P dominates it at every batch
    /// size), so harnesses exercising DT — and callers pinning any other
    /// approach — use this entry point. Validation, fault injection and the
    /// watchdog ladder all still apply; a trip escalates from the forced
    /// approach exactly as it would from a chosen one.
    pub fn apply_update_with(
        &mut self,
        batch: BatchUpdate,
        approach: Approach,
    ) -> Result<UpdateReport> {
        self.apply_update_impl(batch, Some(approach))
    }

    fn apply_update_impl(
        &mut self,
        batch: BatchUpdate,
        force: Option<Approach>,
    ) -> Result<UpdateReport> {
        let seq = self.update_seq;
        self.update_seq += 1;

        // Deterministic fault injection (armed only by the robustness
        // harness; None in production).
        let mut batch = batch;
        let mut result_fault: Option<Fault> = None;
        if let Some(plan) = &mut self.faults {
            match plan.take(seq) {
                Some(Fault::KillCoordinator) => {
                    panic!("injected fault: coordinator killed at update {seq}")
                }
                Some(Fault::MalformedBatch { edits }) => {
                    let junk =
                        plan.malformed_edits(seq, self.builder.num_vertices(), edits);
                    batch.deletions.extend(junk.deletions);
                    batch.insertions.extend(junk.insertions);
                }
                Some(Fault::PoisonPool) => {
                    // Submit a parallel region whose first chunk panics.
                    // The worker pool survives (per-task catch_unwind), but
                    // the submitting coordinator thread observes the typed
                    // `par::PoolPanic` — the supervisor must respawn it
                    // like any other coordinator crash.
                    let mut buf = vec![0u8; 4 * par::DEFAULT_BLOCK];
                    par::par_for(2, par::DEFAULT_BLOCK, &mut buf, |start, _| {
                        if start == 0 {
                            panic!("injected fault: poisoned pool region at update {seq}");
                        }
                    });
                }
                Some(f) => result_fault = Some(f),
                None => {}
            }
        }

        // Validated ingestion: quarantine instead of corrupting the CSR.
        let validated = batch::validate(&self.builder, &batch);
        let quarantined = validated.quarantined();
        self.metrics.record_quarantined(quarantined);
        let clean = validated.clean;
        let rejections = validated.rejections;

        // --- Graph maintenance (timed separately from engine work) ---
        // Apply the clean batch to the builder, fold it into the
        // previous-snapshot delta (so the delta keeps pointing at the graph
        // the last update ran against, even if an engine error exits below),
        // and bring the CSR views up to date: O(batch) patches on the
        // incremental structure, or a full rebuild + transpose in legacy
        // mode.
        let maint_start = Instant::now();
        let edges_changed = batch::apply(&mut self.builder, &clean);
        self.absorb_prev_delta(&clean);
        if let Some(dc) = &mut self.dyn_graph {
            let dc_changed = dc.apply_batch(&clean);
            debug_assert_eq!(dc_changed, edges_changed, "DynCsr diverged from builder");
        }
        let rebuilt: Option<(CsrGraph, CsrGraph)> = if self.dyn_graph.is_none() {
            let g = self.builder.to_csr();
            let gt = g.transpose();
            Some((g, gt))
        } else {
            None
        };

        let mut approach = force.unwrap_or_else(|| {
            self.policy
                .choose(clean.len(), self.builder.num_edges(), self.ranks.is_some())
        });
        // The previous snapshot is only consulted by Dynamic Traversal, and
        // the ladder never escalates *into* DT — materialize it lazily.
        let prev_graph: Option<CsrGraph> =
            matches!(approach, Approach::DynamicTraversal)
                .then(|| self.materialize_prev());
        let maintenance = maint_start.elapsed();
        self.metrics.record_maintenance(maintenance);

        let (g, gt) = match (&self.dyn_graph, &rebuilt) {
            (Some(dc), _) => dc.graphs(),
            (None, Some((g, gt))) => (g, gt),
            (None, None) => unreachable!("one CSR source always exists"),
        };
        let mut trips = 0usize;
        // Degradation ladder: re-run with a more conservative approach until
        // the watchdog accepts the result (at most 3 runs: DF-P → ND →
        // Static). The last-known-good ranks in `self.ranks` are untouched
        // until a healthy result breaks the loop.
        let (res, on_device, approach) = loop {
            let (mut res, on_device) =
                self.run(approach, g, gt, prev_graph.as_ref(), &clean)?;
            if let Some(fault) = result_fault.take() {
                match fault {
                    Fault::CorruptRanks { nans } => {
                        if let Some(plan) = &self.faults {
                            plan.corrupt_ranks(seq, nans, &mut res.ranks);
                        }
                    }
                    Fault::Stall => res.iterations = self.cfg.max_iterations,
                    _ => {}
                }
            }
            let violations = health::check_ranks(
                &res.ranks,
                g.num_vertices(),
                res.iterations,
                &self.cfg,
                &self.health,
            );
            if violations.is_empty() {
                break (res, on_device, approach);
            }
            trips += 1;
            self.metrics.record_watchdog_trip();
            match self.policy.escalate(approach) {
                Some(next) => approach = next,
                None => {
                    // Even a full Static recompute failed the health check:
                    // nothing safe to install; keep serving last-known-good.
                    return Err(HealthError(violations).into());
                }
            }
        };
        if trips > 0 {
            self.metrics.record_recovery();
        }

        self.metrics.record_update(clean.insertions.len(), clean.deletions.len());
        self.metrics.record_run(approach, res.elapsed, res.iterations, on_device);

        let report = UpdateReport {
            approach,
            on_device,
            iterations: res.iterations,
            elapsed: res.elapsed,
            initially_affected: res.initially_affected,
            num_vertices: g.num_vertices(),
            num_edges: g.num_edges(),
            edges_changed,
            quarantined,
            rejections,
            watchdog_trips: trips,
            degraded: self.degraded(),
            maintenance,
        };
        self.ranks = Some(res.ranks);
        // Healthy result installed: the previous snapshot advances to the
        // pre-batch graph — exactly the inverse of the clean batch relative
        // to the current builder (the delta-form of the old
        // `prev_csr = old_csr` assignment).
        self.prev_missing.clear();
        self.prev_extra.clear();
        self.absorb_prev_delta(&clean);
        Ok(report)
    }

    /// Force a full static recomputation (periodic refresh; also resets the
    /// policy's error guard and health degradation). The result is
    /// health-checked like any other: a failed refresh keeps the
    /// last-known-good ranks and the degraded policy state.
    pub fn refresh_static(&mut self) -> Result<UpdateReport> {
        let maint_start = Instant::now();
        let rebuilt: Option<(CsrGraph, CsrGraph)> = if self.dyn_graph.is_none() {
            let g = self.builder.to_csr();
            let gt = g.transpose();
            Some((g, gt))
        } else {
            None
        };
        let maintenance = maint_start.elapsed();
        self.metrics.record_maintenance(maintenance);
        let (g, gt) = match (&self.dyn_graph, &rebuilt) {
            (Some(dc), _) => dc.graphs(),
            (None, Some((g, gt))) => (g, gt),
            (None, None) => unreachable!("one CSR source always exists"),
        };
        let (res, on_device) =
            self.run(Approach::Static, g, gt, None, &BatchUpdate::default())?;
        let violations = health::check_ranks(
            &res.ranks,
            g.num_vertices(),
            res.iterations,
            &self.cfg,
            &self.health,
        );
        if !violations.is_empty() {
            self.metrics.record_watchdog_trip();
            return Err(HealthError(violations).into());
        }
        self.metrics
            .record_run(Approach::Static, res.elapsed, res.iterations, on_device);
        self.policy.reset();
        let report = UpdateReport {
            approach: Approach::Static,
            on_device,
            iterations: res.iterations,
            elapsed: res.elapsed,
            initially_affected: 0,
            num_vertices: g.num_vertices(),
            num_edges: g.num_edges(),
            edges_changed: 0,
            quarantined: 0,
            rejections: Vec::new(),
            watchdog_trips: 0,
            degraded: false,
            maintenance,
        };
        self.ranks = Some(res.ranks);
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generators::er;

    fn service(n: usize) -> DynamicGraphService {
        DynamicGraphService::new(
            er::generate(n, 4.0, 3),
            None, // native-only in unit tests; device covered in tests/
            PagerankConfig::default(),
        )
    }

    #[test]
    fn first_update_is_static_then_dfp() {
        // policy switches to ND above 1e-4|E|, so use a 1-edge batch on a
        // graph with >10k edges to stay in DF-P territory
        let mut s = service(3000);
        let r0 = s.apply_update(BatchUpdate::default()).unwrap();
        assert_eq!(r0.approach, Approach::Static);
        assert!(s.ranks().is_some());

        let b = batch::random_batch(&s.builder, 1, 1.0, 1);
        let r1 = s.apply_update(b).unwrap();
        assert_eq!(r1.approach, Approach::DynamicFrontierPruning);
        assert!(r1.initially_affected > 0);
        assert_eq!(r1.quarantined, 0);
        assert_eq!(r1.watchdog_trips, 0);
        assert!(!r1.degraded);
    }

    #[test]
    fn large_batch_switches_to_nd() {
        let mut s = service(300);
        s.ensure_ranks().unwrap();
        let m = s.num_edges();
        let b = batch::random_batch(&s.builder, m / 100, 0.8, 2); // 1% >> 1e-4
        let r = s.apply_update(b).unwrap();
        assert_eq!(r.approach, Approach::NaiveDynamic);
    }

    #[test]
    fn top_k_sorted() {
        let mut s = service(200);
        s.ensure_ranks().unwrap();
        let top = s.top_k(10);
        assert_eq!(top.len(), 10);
        assert!(top.windows(2).all(|w| w[0].1 >= w[1].1));
    }

    #[test]
    fn top_k_never_panics_on_poisoned_ranks() {
        // the watchdog keeps NaN ranks from ever being installed, but the
        // read path must not rely on that: poison directly and query
        let mut s = service(50);
        s.ensure_ranks().unwrap();
        let n = s.num_vertices();
        let mut poisoned = s.ranks().unwrap().to_vec();
        poisoned[3] = f64::NAN;
        poisoned[7] = f64::NEG_INFINITY;
        s.ranks = Some(poisoned);
        let top = s.top_k(n);
        assert_eq!(top.len(), n, "total_cmp sorts NaN without panicking");
    }

    #[test]
    fn ranks_stay_close_to_static_across_updates() {
        let mut s = service(250);
        s.ensure_ranks().unwrap();
        for seed in 0..5 {
            let b = batch::random_batch(&s.builder, 3, 0.8, seed);
            s.apply_update(b).unwrap();
        }
        let g = s.builder.to_csr();
        let gt = g.transpose();
        let want = native::static_pagerank(&g, &gt, &s.cfg, None).ranks;
        let err: f64 = s
            .ranks()
            .unwrap()
            .iter()
            .zip(&want)
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(err < 1e-2, "accumulated L1 error {err}");
    }

    #[test]
    fn metrics_accumulate() {
        let mut s = service(150);
        s.ensure_ranks().unwrap();
        let b = batch::random_batch(&s.builder, 2, 0.8, 7);
        s.apply_update(b).unwrap();
        assert_eq!(s.metrics.updates_applied, 2);
        assert!(s.metrics.summary().contains("Static"));
    }

    #[test]
    fn malformed_batch_is_quarantined_not_applied() {
        let mut s = service(100);
        s.ensure_ranks().unwrap();
        let n = s.num_vertices() as VertexId;
        let m0 = s.num_edges();
        let b = BatchUpdate {
            deletions: vec![(n, 0), (0, 0)],
            insertions: vec![(n + 5, 1), (2, 2)],
        };
        let rep = s.apply_update(b).unwrap();
        assert_eq!(rep.quarantined, 4);
        assert_eq!(rep.edges_changed, 0);
        assert_eq!(s.num_edges(), m0, "graph untouched by garbage");
        assert_eq!(s.metrics.quarantined_edits, 4);
        assert_eq!(rep.rejections.len(), 4);
    }

    #[test]
    fn incremental_and_rebuild_modes_agree_bitwise() {
        use crate::graph::CsrMode;
        let mk = |mode| {
            DynamicGraphService::new(
                er::generate(400, 5.0, 21),
                None,
                PagerankConfig::default().with_csr_mode(mode),
            )
        };
        let mut inc = mk(CsrMode::Incremental);
        let mut reb = mk(CsrMode::Rebuild);
        inc.ensure_ranks().unwrap();
        reb.ensure_ranks().unwrap();
        for seed in 0..6 {
            // identical builders, so one generated batch is valid for both
            let batch = batch::random_batch(&inc.builder, 12, 0.75, seed);
            let ri = inc.apply_update(batch.clone()).unwrap();
            let rr = reb.apply_update(batch).unwrap();
            assert_eq!(ri.approach, rr.approach, "seed {seed}");
            assert_eq!(ri.iterations, rr.iterations, "seed {seed}");
            for (x, y) in inc.ranks().unwrap().iter().zip(reb.ranks().unwrap()) {
                assert_eq!(x.to_bits(), y.to_bits(), "seed {seed}");
            }
        }
    }

    #[test]
    fn forced_dt_materializes_the_previous_snapshot() {
        let mut s = DynamicGraphService::new(
            er::generate(300, 4.0, 13),
            None,
            PagerankConfig::default().with_csr_mode(crate::graph::CsrMode::Incremental),
        );
        s.ensure_ranks().unwrap();
        let b1 = batch::random_batch(&s.builder, 4, 0.8, 1);
        s.apply_update(b1).unwrap();
        // forcing DT exercises materialize_prev (the lazy old-graph path)
        let b2 = batch::random_batch(&s.builder, 4, 0.8, 2);
        let rep = s.apply_update_with(b2, Approach::DynamicTraversal).unwrap();
        assert_eq!(rep.approach, Approach::DynamicTraversal);
        assert!(rep.initially_affected > 0);
    }

    #[test]
    fn checkpoint_restore_resumes_warm() {
        let mut s = service(200);
        s.ensure_ranks().unwrap();
        let b = batch::random_batch(&s.builder, 3, 0.8, 5);
        s.apply_update(b).unwrap();

        let cp = s.checkpoint();
        assert_eq!(cp.seq, 2);
        let mut r = DynamicGraphService::restore(&cp, None).unwrap();
        assert_eq!(r.num_vertices(), s.num_vertices());
        assert_eq!(r.num_edges(), s.num_edges());
        assert_eq!(r.metrics.restores, 1);
        assert_eq!(r.update_seq(), 2);
        for (a, b) in r.ranks().unwrap().iter().zip(s.ranks().unwrap()) {
            assert_eq!(a.to_bits(), b.to_bits(), "warm ranks carried over");
        }
        // a restored service keeps updating
        let b = batch::random_batch(&r.builder, 2, 0.8, 9);
        let rep = r.apply_update(b).unwrap();
        assert_ne!(rep.approach, Approach::Static, "warm restart, not cold");
    }
}
