//! Rank error measurement (paper Section 5.1.5): L1 norm of the returned
//! ranks against a reference static run at τ = 1e-100 capped at 500
//! iterations.

use super::config::PagerankConfig;
use super::native::static_pagerank;
use crate::graph::CsrGraph;

/// L1 distance between two rank vectors.
pub fn l1_distance(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum()
}

/// L∞ distance.
pub fn linf_distance(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

/// Reference ranks per Section 5.1.5 (τ = 1e-100, 500 iterations).
pub fn reference_ranks(g: &CsrGraph, gt: &CsrGraph) -> Vec<f64> {
    static_pagerank(g, gt, &PagerankConfig::reference(), None).ranks
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generators::er;

    #[test]
    fn distances() {
        let a = [0.5, 0.25, 0.25];
        let b = [0.25, 0.5, 0.25];
        assert_eq!(l1_distance(&a, &b), 0.5);
        assert_eq!(linf_distance(&a, &b), 0.25);
        assert_eq!(l1_distance(&a, &a), 0.0);
    }

    #[test]
    fn reference_tighter_than_default() {
        let g = er::generate(200, 5.0, 1).to_csr();
        let gt = g.transpose();
        let reference = reference_ranks(&g, &gt);
        let normal = static_pagerank(&g, &gt, &PagerankConfig::default(), None);
        // default-τ run is close to the reference, but not beyond it
        assert!(l1_distance(&normal.ranks, &reference) < 1e-7);
    }
}
