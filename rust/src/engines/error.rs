//! Rank error measurement (paper Section 5.1.5): L1 norm of the returned
//! ranks against a reference static run at τ = 1e-100 capped at 500
//! iterations.
//!
//! The distance functions return a typed [`LengthMismatch`] instead of
//! asserting: once checkpoints and restarts interleave, the two vectors can
//! legitimately come from snapshots with different vertex counts, and the
//! serving path must degrade gracefully rather than abort.
//!
//! Both norms run through the `util::simd` striped lane-tree kernels
//! (auto-detected backend; bitwise identical on scalar and vector units).
//! A `-0.0` vs `0.0` element contributes exactly `+0.0` to either norm —
//! the difference is `±0.0` and `abs` folds the sign — so a semantically
//! equal sign bit can never register as error. NaN differences propagate
//! into the result (the health watchdog screens for NaN ranks separately).

use std::fmt;

use super::config::PagerankConfig;
use super::native::static_pagerank;
use crate::graph::CsrGraph;
use crate::util::simd::{self, SimdPolicy};

/// Two rank vectors with different vertex counts were compared.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LengthMismatch {
    pub left: usize,
    pub right: usize,
}

impl fmt::Display for LengthMismatch {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "rank vector length mismatch: {} vs {} vertices",
            self.left, self.right
        )
    }
}

impl std::error::Error for LengthMismatch {}

fn check_lengths(a: &[f64], b: &[f64]) -> Result<(), LengthMismatch> {
    if a.len() != b.len() {
        return Err(LengthMismatch { left: a.len(), right: b.len() });
    }
    Ok(())
}

/// L1 distance between two rank vectors.
pub fn l1_distance(a: &[f64], b: &[f64]) -> Result<f64, LengthMismatch> {
    check_lengths(a, b)?;
    Ok(simd::l1(simd::resolve(SimdPolicy::Auto), a, b))
}

/// L∞ distance.
pub fn linf_distance(a: &[f64], b: &[f64]) -> Result<f64, LengthMismatch> {
    check_lengths(a, b)?;
    Ok(simd::linf(simd::resolve(SimdPolicy::Auto), a, b))
}

/// Reference ranks per Section 5.1.5 (τ = 1e-100, 500 iterations).
pub fn reference_ranks(g: &CsrGraph, gt: &CsrGraph) -> Vec<f64> {
    static_pagerank(g, gt, &PagerankConfig::reference(), None).ranks
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generators::er;

    #[test]
    fn distances() {
        let a = [0.5, 0.25, 0.25];
        let b = [0.25, 0.5, 0.25];
        assert_eq!(l1_distance(&a, &b).unwrap(), 0.5);
        assert_eq!(linf_distance(&a, &b).unwrap(), 0.25);
        assert_eq!(l1_distance(&a, &a).unwrap(), 0.0);
    }

    #[test]
    fn negative_zero_is_no_error() {
        // -0.0 == 0.0: a sign-of-zero mismatch between two rank vectors
        // must contribute exactly nothing to either norm
        let a = [0.0, -0.0, 0.25];
        let b = [-0.0, 0.0, 0.25];
        assert_eq!(l1_distance(&a, &b).unwrap().to_bits(), 0.0f64.to_bits());
        assert_eq!(linf_distance(&a, &b).unwrap().to_bits(), 0.0f64.to_bits());
    }

    #[test]
    fn length_mismatch_is_typed_not_fatal() {
        let a = [0.5, 0.5];
        let b = [1.0];
        let err = l1_distance(&a, &b).unwrap_err();
        assert_eq!(err, LengthMismatch { left: 2, right: 1 });
        assert!(err.to_string().contains("2 vs 1"));
        assert!(linf_distance(&a, &b).is_err());
        // converts into anyhow::Error through `?`
        let as_anyhow: anyhow::Error = err.into();
        assert!(as_anyhow.to_string().contains("mismatch"));
    }

    #[test]
    fn reference_tighter_than_default() {
        let g = er::generate(200, 5.0, 1).to_csr();
        let gt = g.transpose();
        let reference = reference_ranks(&g, &gt);
        let normal = static_pagerank(&g, &gt, &PagerankConfig::default(), None);
        // default-τ run is close to the reference, but not beyond it
        assert!(l1_distance(&normal.ranks, &reference).unwrap() < 1e-7);
    }
}
