//! Device ("GPU") engines: the paper's contribution, executed as AOT HLO
//! artifacts on the PJRT backend.
//!
//! Each PageRank run is a Rust-driven loop over compiled step/expand
//! executables (one launch per kernel pair, as in the paper). The rank
//! vector and affected flags live in a **device-resident packed state
//! buffer** threaded from one launch to the next; per iteration the host
//! reads back only the 8-byte L∞ delta via a `peek` program (and, in
//! worklist mode, the flag segments) — mirroring the paper's
//! convergence-detection transfer.

use std::time::Instant;

use anyhow::{ensure, Result};

use crate::batch::BatchUpdate;
use crate::engines::config::PagerankConfig;
use crate::engines::native::affected::{dt_affected, expand_affected, initial_affected};
use crate::engines::{Approach, PagerankResult};
use crate::graph::CsrGraph;
use crate::runtime::exec::{buf_f64, buf_i32, exec1, read_f64, read_scalar, GraphBufs};
use crate::runtime::{ArtifactStore, DeviceGraph};

/// Work-partitioning strategy between the thread-per-vertex and
/// block-per-vertex kernels (the paper's Figure 1 ablation, plus our
/// gather-based expansion refinement).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PartitionMode {
    /// "Don't Partition": rank update via the flat edge-list segmented
    /// reduction; expansion via the flat scatter.
    DontPartition,
    /// "Partition G'": in-degree-partitioned rank kernels; flat expansion.
    PartitionGPrime,
    /// "Partition G, G'": partitioned rank kernels + out-degree-partitioned
    /// scatter expansion (the paper's best configuration).
    PartitionBoth,
    /// Partition G, G' with our pull (gather, atomics-free) expansion.
    PartitionBothPull,
}

impl PartitionMode {
    /// Parse a CLI name (nopart / gprime / both / both-pull).
    pub fn parse(s: &str) -> Option<PartitionMode> {
        match s.to_ascii_lowercase().as_str() {
            "nopart" | "dont-partition" => Some(PartitionMode::DontPartition),
            "gprime" | "partition-gprime" => Some(PartitionMode::PartitionGPrime),
            "both" | "partition-both" => Some(PartitionMode::PartitionBoth),
            "both-pull" | "partition-both-pull" => Some(PartitionMode::PartitionBothPull),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            PartitionMode::DontPartition => "Don't Partition",
            PartitionMode::PartitionGPrime => "Partition G'",
            PartitionMode::PartitionBoth => "Partition G, G'",
            PartitionMode::PartitionBothPull => "Partition G, G' (pull)",
        }
    }
}

/// The artifact-backed engine. Holds a reference to the executable store;
/// cheap to construct per call site.
pub struct DeviceEngine<'a> {
    store: &'a ArtifactStore,
}

impl<'a> DeviceEngine<'a> {
    pub fn new(store: &'a ArtifactStore) -> Self {
        Self { store }
    }

    pub fn store(&self) -> &ArtifactStore {
        self.store
    }

    /// The engine's tolerances are partly baked into the artifacts; reject
    /// configs that silently diverge from them.
    fn check_config(&self, cfg: &PagerankConfig) -> Result<()> {
        let c = &self.store.manifest().constants;
        ensure!(cfg.alpha == c.alpha, "alpha {} != baked {}", cfg.alpha, c.alpha);
        ensure!(
            cfg.tau_frontier == c.tau_frontier && cfg.tau_prune == c.tau_prune,
            "frontier/prune tolerances differ from baked artifact constants"
        );
        Ok(())
    }

    fn initial_ranks(&self, dg: &DeviceGraph, r0: Option<&[f64]>) -> Vec<f64> {
        match r0 {
            Some(prev) => dg.pad(prev),
            None => {
                let mut v = vec![0.0f64; dg.tier.v];
                v[..dg.n].fill(1.0 / dg.n as f64);
                v
            }
        }
    }

    /// Shared Static/ND/DT loop over the `state1 = [r | linf]` layout.
    fn run_state1(
        &self,
        dg: &DeviceGraph,
        cfg: &PagerankConfig,
        r0: Option<&[f64]>,
        aff: Option<&[f64]>, // DT's fixed mask (tier-shaped)
    ) -> Result<(Vec<f64>, usize, std::time::Duration)> {
        self.check_config(cfg)?;
        let tier = &dg.tier.name;
        let step_name = if aff.is_some() { "step_dt" } else { "step_plain" };
        let exe_step = self.store.executable(step_name, tier)?;
        let exe_peek = self.store.executable("peek_linf1", tier)?;
        let bufs = GraphBufs::build(self.store, dg)?;
        let aff_buf = match aff {
            Some(a) => Some(buf_f64(self.store, a, &[dg.tier.v])?),
            None => None,
        };

        let mut host_state = self.initial_ranks(dg, r0);
        host_state.push(0.0); // linf slot
        let mut state = buf_f64(self.store, &host_state, &[dg.tier.v + 1])?;

        let start = Instant::now();
        let mut iterations = 0;
        for _ in 0..cfg.max_iterations {
            let mut args: Vec<&xla::PjRtBuffer> = vec![
                &state,
                &bufs.odi,
                &bufs.valid,
                &bufs.inv_n,
                &bufs.ell,
                &bufs.hub_edges,
                &bufs.hub_seg,
            ];
            if let Some(a) = &aff_buf {
                args.push(a);
            }
            state = exec1(&exe_step, &args)?;
            iterations += 1;
            let linf = read_scalar(&exec1(&exe_peek, &[&state])?)?;
            if linf <= cfg.tau {
                break;
            }
        }
        let elapsed = start.elapsed() + dg.pack_time;
        let mut ranks = read_f64(&state)?;
        ranks.truncate(dg.n);
        Ok((ranks, iterations, elapsed))
    }

    /// Static PageRank (Algorithm 1) — or Naive-dynamic when `r0` is given.
    pub fn static_pagerank(
        &self,
        dg: &DeviceGraph,
        cfg: &PagerankConfig,
        r0: Option<&[f64]>,
    ) -> Result<PagerankResult> {
        let (ranks, iterations, elapsed) = self.run_state1(dg, cfg, r0, None)?;
        Ok(PagerankResult::new(ranks, iterations, elapsed))
    }

    /// Naive-dynamic: warm start from the previous ranks.
    pub fn naive_dynamic(
        &self,
        dg: &DeviceGraph,
        cfg: &PagerankConfig,
        prev: &[f64],
    ) -> Result<PagerankResult> {
        self.static_pagerank(dg, cfg, Some(prev))
    }

    /// Dynamic Traversal: host BFS marking (old + new graph), then masked
    /// device iterations over the fixed affected set.
    pub fn dynamic_traversal(
        &self,
        dg: &DeviceGraph,
        g: &CsrGraph,
        g_old: &CsrGraph,
        cfg: &PagerankConfig,
        prev: &[f64],
        batch: &BatchUpdate,
    ) -> Result<PagerankResult> {
        let mark_start = Instant::now();
        let aff_u8 = dt_affected(g, g_old, batch);
        let marking = mark_start.elapsed();
        let initially_affected = aff_u8.iter().filter(|&&x| x != 0).count();
        let aff_f: Vec<f64> = aff_u8.iter().map(|&x| x as f64).collect();
        let aff = dg.pad(&aff_f);
        let (ranks, iterations, elapsed) =
            self.run_state1(dg, cfg, Some(prev), Some(&aff))?;
        Ok(PagerankResult {
            ranks,
            iterations,
            elapsed: elapsed + marking, // marking counts per Section 5.1.5
            initially_affected,
        })
    }

    /// Dynamic Frontier (`prune=false`) / DF-P (`prune=true`), Algorithm 2.
    ///
    /// `mode` selects the Figure-1 work partitioning; `use_worklist` enables
    /// the compacted step/expand variants when the frontier fits their
    /// capacity (the fixed-shape analog of the GPU skipping unaffected
    /// vertices). `g` is the current out-adjacency (host side), used to
    /// project the post-expansion frontier for worklist construction.
    #[allow(clippy::too_many_arguments)]
    pub fn dynamic_frontier(
        &self,
        dg: &DeviceGraph,
        g: &CsrGraph,
        cfg: &PagerankConfig,
        prev: &[f64],
        batch: &BatchUpdate,
        prune: bool,
        mode: PartitionMode,
        use_worklist: bool,
    ) -> Result<PagerankResult> {
        self.check_config(cfg)?;
        let tier = &dg.tier.name;
        let v = dg.tier.v;
        let base = if prune { "step_dfp" } else { "step_df" };
        let (step_name, expand_name) = match mode {
            PartitionMode::DontPartition => (format!("{base}_nopart"), "expand_flat"),
            PartitionMode::PartitionGPrime => (base.to_string(), "expand_flat"),
            PartitionMode::PartitionBoth => (base.to_string(), "expand_scatter"),
            PartitionMode::PartitionBothPull => (base.to_string(), "expand_pull"),
        };
        let exe_step = self.store.executable(&step_name, tier)?;
        let exe_expand = self.store.executable(expand_name, tier)?;
        let exe_peek = self.store.executable("peek_linf3", tier)?;
        let compacted = use_worklist && mode != PartitionMode::DontPartition;
        let exe_step_wl = if compacted {
            Some(self.store.executable(&format!("{base}_wl"), tier)?)
        } else {
            None
        };
        let exe_expand_wl = if compacted {
            Some(self.store.executable("expand_scatter_wl", tier)?)
        } else {
            None
        };
        let exe_peek_ad = if compacted {
            Some(self.store.executable("peek_aff_dn", tier)?)
        } else {
            None
        };
        let bufs = GraphBufs::build(self.store, dg)?;

        let start = Instant::now();
        // Algorithm 5 initialAffected on the host (O(|batch|)).
        let (dv0, dn0) = initial_affected(dg.n, batch);
        let mut host_state = vec![0.0f64; 3 * v + 1];
        host_state[..v].copy_from_slice(&self.initial_ranks(dg, Some(prev)));
        for i in 0..dg.n {
            host_state[v + i] = dv0[i] as f64;
            host_state[2 * v + i] = dn0[i] as f64;
        }
        let mut state = buf_f64(self.store, &host_state, &[3 * v + 1])?;

        // host mirror of the frontier (worklist construction + metrics);
        // kept exact by re-applying the same expansions the device does.
        let mut dv_host = dv0;
        let dn_host: Vec<f64> = host_state[2 * v..3 * v].to_vec();

        // initial expansion: mark out-neighbors of update sources (device),
        // mirrored on host.
        state = self.expand(
            &exe_expand,
            exe_expand_wl.as_deref(),
            dg,
            &bufs,
            mode,
            state,
            &dn_host,
        )?;
        expand_affected(&mut dv_host, &dn0, g);
        let initially_affected = dv_host.iter().filter(|&&x| x != 0).count();
        let mut aff_approx: Vec<f64> = {
            let mut a = vec![0.0f64; v];
            for i in 0..dg.n {
                a[i] = dv_host[i] as f64;
            }
            a
        };

        let mut iterations = 0;
        for _ in 0..cfg.max_iterations {
            // pick compacted or full-shape step using the host frontier view
            let wl = if compacted {
                dg.worklists(&aff_approx, &dg.in_side)
            } else {
                None
            };
            state = match (&exe_step_wl, wl) {
                (Some(exe_wl), Some((wl, wlc))) => {
                    let wl_b = buf_i32(self.store, &wl, &[dg.tier.wl_cap])?;
                    let wlc_b = buf_i32(self.store, &wlc, &[dg.tier.wl_chunk_cap])?;
                    exec1(exe_wl, &[
                        &state,
                        &bufs.odi,
                        &bufs.valid,
                        &bufs.inv_n,
                        &bufs.ell,
                        &bufs.hub_edges,
                        &bufs.hub_seg,
                        &wl_b,
                        &wlc_b,
                    ])?
                }
                _ => {
                    if mode == PartitionMode::DontPartition {
                        exec1(&exe_step, &[
                            &state,
                            &bufs.odi,
                            &bufs.valid,
                            &bufs.inv_n,
                            &bufs.te_src,
                            &bufs.te_dst,
                        ])?
                    } else {
                        exec1(&exe_step, &[
                            &state,
                            &bufs.odi,
                            &bufs.valid,
                            &bufs.inv_n,
                            &bufs.ell,
                            &bufs.hub_edges,
                            &bufs.hub_seg,
                        ])?
                    }
                }
            };
            iterations += 1;
            let linf = read_scalar(&exec1(&exe_peek, &[&state])?)?;
            if linf <= cfg.tau {
                break;
            }

            // worklist mode: fetch post-step flags to drive the compacted
            // expansion and the next step's worklist.
            let dn_now: Vec<f64> = if let Some(peek_ad) = &exe_peek_ad {
                let ad = read_f64(&exec1(peek_ad, &[&state])?)?;
                // next-step frontier = post-prune aff ∪ out-neighbors(dn)
                aff_approx.copy_from_slice(&ad[..v]);
                let dn = ad[v..].to_vec();
                for u in 0..dg.n {
                    if dn[u] > 0.0 {
                        for &w in g.neighbors(u as u32) {
                            aff_approx[w as usize] = 1.0;
                        }
                    }
                }
                dn
            } else {
                Vec::new()
            };
            state = self.expand(
                &exe_expand,
                exe_expand_wl.as_deref(),
                dg,
                &bufs,
                mode,
                state,
                &dn_now,
            )?;
        }
        let elapsed = start.elapsed() + dg.pack_time;
        let mut ranks = read_f64(&state)?;
        ranks.truncate(dg.n);
        Ok(PagerankResult { ranks, iterations, elapsed, initially_affected })
    }

    /// One frontier expansion launch (Algorithm 5 expandAffected), using the
    /// compacted scatter when a worklist over `dn_host` fits, else the
    /// mode's full kernel.
    #[allow(clippy::too_many_arguments)]
    fn expand(
        &self,
        exe_expand: &xla::PjRtLoadedExecutable,
        exe_expand_wl: Option<&xla::PjRtLoadedExecutable>,
        dg: &DeviceGraph,
        bufs: &GraphBufs,
        mode: PartitionMode,
        state: xla::PjRtBuffer,
        dn_host: &[f64],
    ) -> Result<xla::PjRtBuffer> {
        if let Some(exe_wl) = exe_expand_wl {
            if !dn_host.is_empty() {
                if let Some((wl, wlc)) = dg.worklists(dn_host, &dg.out_side) {
                    let wl_b = buf_i32(self.store, &wl, &[dg.tier.wl_cap])?;
                    let wlc_b = buf_i32(self.store, &wlc, &[dg.tier.wl_chunk_cap])?;
                    return exec1(exe_wl, &[
                        &state,
                        &bufs.out_ell,
                        &bufs.out_hub_edges,
                        &bufs.out_hub_seg,
                        &wl_b,
                        &wlc_b,
                    ]);
                }
            }
        }
        match mode {
            PartitionMode::DontPartition | PartitionMode::PartitionGPrime => {
                exec1(exe_expand, &[&state, &bufs.te_src, &bufs.te_dst])
            }
            PartitionMode::PartitionBoth => exec1(exe_expand, &[
                &state,
                &bufs.out_ell,
                &bufs.out_hub_edges,
                &bufs.out_hub_seg,
            ]),
            PartitionMode::PartitionBothPull => exec1(exe_expand, &[
                &state,
                &bufs.ell,
                &bufs.hub_edges,
                &bufs.hub_seg,
            ]),
        }
    }

    /// Dispatch by approach (used by the coordinator and the harness).
    #[allow(clippy::too_many_arguments)]
    pub fn run_approach(
        &self,
        approach: Approach,
        dg: &DeviceGraph,
        g: &CsrGraph,
        g_old: &CsrGraph,
        cfg: &PagerankConfig,
        prev: Option<&[f64]>,
        batch: &BatchUpdate,
    ) -> Result<PagerankResult> {
        match approach {
            Approach::Static => self.static_pagerank(dg, cfg, None),
            Approach::NaiveDynamic => {
                self.naive_dynamic(dg, cfg, prev.expect("ND needs previous ranks"))
            }
            Approach::DynamicTraversal => self.dynamic_traversal(
                dg,
                g,
                g_old,
                cfg,
                prev.expect("DT needs previous ranks"),
                batch,
            ),
            Approach::DynamicFrontier => self.dynamic_frontier(
                dg,
                g,
                cfg,
                prev.expect("DF needs previous ranks"),
                batch,
                false,
                PartitionMode::PartitionBothPull,
                true,
            ),
            Approach::DynamicFrontierPruning => self.dynamic_frontier(
                dg,
                g,
                cfg,
                prev.expect("DF-P needs previous ranks"),
                batch,
                true,
                PartitionMode::PartitionBothPull,
                true,
            ),
        }
    }
}
