//! Gunrock-style Static PageRank (Wang et al. [58], as characterized in the
//! paper's Section 2.1):
//!
//! - push-based with **atomic adds per edge** (thrust-style parallel-for
//!   over the vertex id range);
//! - computes the **global teleport contribution due to dead ends** with a
//!   dedicated kernel every iteration (even though our graphs carry
//!   self-loops, Gunrock still pays the scan);
//! - no low/high degree partitioning.

use std::sync::atomic::Ordering;
use std::time::Instant;

use super::{atomic_add_f64, atomic_zeros};
use crate::engines::config::PagerankConfig;
use crate::engines::PagerankResult;
use crate::graph::CsrGraph;

/// Run Gunrock-like Static PageRank on `g` (out-adjacency).
pub fn gunrock_like(g: &CsrGraph, cfg: &PagerankConfig) -> PagerankResult {
    let n = g.num_vertices();
    let start = Instant::now();
    let mut r = vec![1.0 / n as f64; n];
    let c0 = (1.0 - cfg.alpha) / n as f64;

    let mut iterations = 0;
    for _ in 0..cfg.max_iterations {
        // dead-end teleport kernel: full scan summing the rank of every
        // zero-out-degree vertex (always 0 here — the pass is the cost)
        let dangling: f64 = (0..n as u32)
            .map(|v| if g.degree(v) == 0 { r[v as usize] } else { 0.0 })
            .sum();
        let teleport = cfg.alpha * dangling / n as f64;

        // push kernel: parallel for over vertex ids, atomic add per edge
        let acc = atomic_zeros(n);
        for u in 0..n as u32 {
            let s = r[u as usize] / g.degree(u) as f64;
            for &v in g.neighbors(u) {
                atomic_add_f64(&acc[v as usize], s);
            }
        }

        // rank assembly + tree-reduced L∞ norm (Gunrock reduces properly)
        let (r_new, linf): (Vec<f64>, f64) = {
            let r_ref = &r;
            let pairs: Vec<(f64, f64)> = (0..n)
                .map(|v| {
                    let c = f64::from_bits(acc[v].load(Ordering::Relaxed));
                    let nr = c0 + cfg.alpha * c + teleport;
                    (nr, (nr - r_ref[v]).abs())
                })
                .collect();
            let linf = pairs.iter().map(|&(_, d)| d).fold(0.0, f64::max);
            (pairs.into_iter().map(|(nr, _)| nr).collect(), linf)
        };

        r = r_new;
        iterations += 1;
        if linf <= cfg.tau {
            break;
        }
    }
    PagerankResult::new(r, iterations, start.elapsed())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generators::er;

    #[test]
    fn converges_and_sums_to_one() {
        let g = er::generate(400, 5.0, 3).to_csr();
        let res = gunrock_like(&g, &PagerankConfig::default());
        assert!((res.ranks.iter().sum::<f64>() - 1.0).abs() < 1e-6);
        assert!(res.iterations < 200);
    }
}
