//! Baseline Static PageRank implementations modeling Hornet's and Gunrock's
//! algorithmic choices (paper Sections 2.1, 5.2) on this testbed.
//!
//! These are *structural* comparators: we cannot run the CUDA frameworks
//! here, so each baseline reimplements the per-iteration work the paper
//! attributes to it — push-based scatter with one atomic add per edge,
//! separate contribution/rank kernels, global teleport computation, naive
//! norm reduction — while converging to the same ranks. The extra memory
//! passes and atomic traffic are exactly what the paper's pull-based,
//! partitioned implementation eliminates, so the relative ordering
//! (ours < Gunrock < Hornet) carries over; see EXPERIMENTS.md Table 1 for
//! the measured factors.

pub mod gunrock_like;
pub mod hornet_like;

use std::sync::atomic::{AtomicU64, Ordering};

pub use gunrock_like::gunrock_like;
pub use hornet_like::hornet_like;

/// Atomic f64 add via CAS on the bit pattern — the cost model for the
/// per-edge atomic adds both frameworks issue on the GPU.
#[inline]
pub(crate) fn atomic_add_f64(cell: &AtomicU64, value: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let next = f64::from_bits(cur) + value;
        match cell.compare_exchange_weak(
            cur,
            next.to_bits(),
            Ordering::Relaxed,
            Ordering::Relaxed,
        ) {
            Ok(_) => return,
            Err(actual) => cur = actual,
        }
    }
}

/// Zeroed atomic accumulator vector.
pub(crate) fn atomic_zeros(n: usize) -> Vec<AtomicU64> {
    (0..n).map(|_| AtomicU64::new(0f64.to_bits())).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engines::config::PagerankConfig;
    use crate::engines::error::l1_distance;
    use crate::engines::native::static_pagerank;
    use crate::generators::{er, rmat};

    #[test]
    fn atomic_add_accumulates() {
        let cell = AtomicU64::new(0f64.to_bits());
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..250 {
                        atomic_add_f64(&cell, 0.5);
                    }
                });
            }
        });
        assert_eq!(f64::from_bits(cell.load(Ordering::Relaxed)), 500.0);
    }

    #[test]
    fn baselines_match_native_ranks() {
        let cfg = PagerankConfig::default();
        for g in [
            er::generate(300, 5.0, 1).to_csr(),
            rmat::generate(9, 6.0, rmat::RmatParams::WEB, 2).to_csr(),
        ] {
            let gt = g.transpose();
            let want = static_pagerank(&g, &gt, &cfg, None).ranks;
            let h = hornet_like(&g, &cfg);
            let k = gunrock_like(&g, &cfg);
            assert!(l1_distance(&h.ranks, &want).unwrap() < 1e-6, "hornet");
            assert!(l1_distance(&k.ranks, &want).unwrap() < 1e-6, "gunrock");
        }
    }
}
