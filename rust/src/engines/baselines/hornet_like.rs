//! Hornet-style Static PageRank (Busato et al. [8], as characterized in the
//! paper's Section 2.1):
//!
//! - push-based: one **atomic add per edge** into a contribution vector;
//! - the per-vertex rank contribution is computed **separately** and stored
//!   in a distinct vector (extra kernel + extra memory pass);
//! - an **additional kernel** computes ranks from the accumulated
//!   contributions;
//! - the convergence norm is a **naive atomic reduction** rather than a
//!   tree reduce;
//! - thread-per-vertex parallel for over all vertices, no degree
//!   partitioning (load imbalance on hubs).

use std::sync::atomic::Ordering;
use std::time::Instant;

use super::{atomic_add_f64, atomic_zeros};
use crate::engines::config::PagerankConfig;
use crate::engines::PagerankResult;
use crate::graph::CsrGraph;

/// Run Hornet-like Static PageRank on `g` (out-adjacency).
pub fn hornet_like(g: &CsrGraph, cfg: &PagerankConfig) -> PagerankResult {
    let n = g.num_vertices();
    let start = Instant::now();
    let mut r = vec![1.0 / n as f64; n];
    let mut share = vec![0.0f64; n];
    let c0 = (1.0 - cfg.alpha) / n as f64;

    let mut iterations = 0;
    for _ in 0..cfg.max_iterations {
        // kernel 1: per-vertex share vector (Hornet's separate
        // "rank contribution" computation)
        for (u, s) in share.iter_mut().enumerate() {
            *s = r[u] / g.degree(u as u32) as f64;
        }

        // kernel 2: push — one atomic add per edge, thread per vertex
        let acc = atomic_zeros(n);
        for u in 0..n as u32 {
            let s = share[u as usize];
            for &v in g.neighbors(u) {
                atomic_add_f64(&acc[v as usize], s);
            }
        }

        // kernel 3: ranks from contributions + naive atomic max-norm
        let norm = atomic_zeros(1);
        let r_new: Vec<f64> = (0..n)
            .map(|v| {
                let c = f64::from_bits(acc[v].load(Ordering::Relaxed));
                let nr = c0 + cfg.alpha * c;
                // Hornet's naive atomic norm update (per vertex)
                let d = (nr - r[v]).abs();
                let cell = &norm[0];
                let mut cur = cell.load(Ordering::Relaxed);
                while d > f64::from_bits(cur) {
                    match cell.compare_exchange_weak(
                        cur,
                        d.to_bits(),
                        Ordering::Relaxed,
                        Ordering::Relaxed,
                    ) {
                        Ok(_) => break,
                        Err(actual) => cur = actual,
                    }
                }
                nr
            })
            .collect();

        r = r_new;
        iterations += 1;
        if f64::from_bits(norm[0].load(Ordering::Relaxed)) <= cfg.tau {
            break;
        }
    }
    PagerankResult::new(r, iterations, start.elapsed())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generators::er;

    #[test]
    fn converges_and_sums_to_one() {
        let g = er::generate(400, 5.0, 3).to_csr();
        let res = hornet_like(&g, &PagerankConfig::default());
        assert!((res.ranks.iter().sum::<f64>() - 1.0).abs() < 1e-6);
        assert!(res.iterations < 200);
    }
}
