//! PageRank configuration — the paper's Section 5.1.2 settings as defaults.

/// Tolerances and limits shared by every engine and approach.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PagerankConfig {
    /// Damping factor α (paper: 0.85).
    pub alpha: f64,
    /// Iteration tolerance τ on the L∞ rank delta (paper: 1e-10).
    pub tau: f64,
    /// Frontier tolerance τ_f: relative rank change above this marks the
    /// vertex's out-neighbors affected (paper: 1e-6).
    pub tau_frontier: f64,
    /// Prune tolerance τ_p: relative rank change at or below this unflags
    /// the vertex in DF-P (paper: 1e-6).
    pub tau_prune: f64,
    /// MAX_ITERATIONS (paper: 500).
    pub max_iterations: usize,
    /// Worker threads for the native engines' scoped-thread pool
    /// (`util::par`). `0` (the default) means "all available cores";
    /// `1` runs the same blocked loops inline (sequential). Results are
    /// bit-identical at every setting — see `util::par`.
    pub threads: usize,
}

impl Default for PagerankConfig {
    fn default() -> Self {
        Self {
            alpha: 0.85,
            tau: 1e-10,
            tau_frontier: 1e-6,
            tau_prune: 1e-6,
            max_iterations: 500,
            threads: 0,
        }
    }
}

impl PagerankConfig {
    /// The reference configuration of Section 5.1.5: an unreachably small
    /// tolerance so the run is capped by `max_iterations` (500).
    pub fn reference() -> Self {
        Self { tau: 1e-100, ..Self::default() }
    }

    /// This configuration with an explicit native-pool thread count.
    pub fn with_threads(self, threads: usize) -> Self {
        Self { threads, ..self }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = PagerankConfig::default();
        assert_eq!(c.alpha, 0.85);
        assert_eq!(c.tau, 1e-10);
        assert_eq!(c.tau_frontier, 1e-6);
        assert_eq!(c.tau_prune, 1e-6);
        assert_eq!(c.max_iterations, 500);
        assert_eq!(c.threads, 0, "0 = use available parallelism");
        assert!(crate::util::par::resolve(c.threads) >= 1);
    }

    #[test]
    fn with_threads_builder() {
        let c = PagerankConfig::default().with_threads(4);
        assert_eq!(c.threads, 4);
        assert_eq!(c.alpha, 0.85);
    }
}
