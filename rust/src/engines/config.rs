//! PageRank configuration — the paper's Section 5.1.2 settings as defaults.

use crate::graph::CsrMode;
use crate::util::simd::SimdPolicy;
use std::fmt;

/// A [`PagerankConfig`] field holds a value no engine can run with.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ConfigError {
    /// Damping factor outside (0, 1).
    Alpha(f64),
    /// A tolerance (τ, τ_f or τ_p) that is negative or non-finite.
    Tolerance(&'static str, f64),
    /// `max_iterations == 0`: no engine would ever produce ranks.
    ZeroIterations,
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ConfigError::Alpha(a) => write!(f, "alpha {a} outside (0, 1)"),
            ConfigError::Tolerance(name, v) => {
                write!(f, "{name} = {v} must be finite and non-negative")
            }
            ConfigError::ZeroIterations => write!(f, "max_iterations must be >= 1"),
        }
    }
}

impl std::error::Error for ConfigError {}

/// Tolerances and limits shared by every engine and approach.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PagerankConfig {
    /// Damping factor α (paper: 0.85).
    pub alpha: f64,
    /// Iteration tolerance τ on the L∞ rank delta (paper: 1e-10).
    pub tau: f64,
    /// Frontier tolerance τ_f: relative rank change above this marks the
    /// vertex's out-neighbors affected (paper: 1e-6).
    pub tau_frontier: f64,
    /// Prune tolerance τ_p: relative rank change at or below this unflags
    /// the vertex in DF-P (paper: 1e-6).
    pub tau_prune: f64,
    /// MAX_ITERATIONS (paper: 500).
    pub max_iterations: usize,
    /// Worker lanes for the native engines' parallel regions (`util::par`).
    /// `0` (the default) means "all available cores" (overridable with the
    /// `PAGERANK_THREADS` environment variable); `1` runs the same blocked
    /// loops inline (sequential). Results are bit-identical at every
    /// setting — see `util::par`.
    pub threads: usize,
    /// `true` (the default): parallel regions run on the lazily-initialized
    /// persistent work-stealing pool, amortizing thread spawns and letting
    /// idle lanes steal skewed hub/frontier chunks. `false`: per-region
    /// scoped spawning with static round-robin lanes (the pre-pool
    /// behavior, kept as an escape hatch and as the equivalence reference
    /// for `tests/pool_determinism.rs`). Ranks are bitwise identical either
    /// way; only wall-clock changes.
    pub pool_persistent: bool,
    /// SIMD backend for the native engines' inner loops (`util::simd`):
    /// `Auto` (the default) uses the detected vector unit unless the
    /// `PAGERANK_SIMD=0` environment pin forces the portable scalar loops;
    /// `Scalar`/`Vector` override the environment. Ranks are bitwise
    /// identical at every setting — both backends obey the same fixed
    /// lane-tree reduction order; only wall-clock changes.
    pub simd: SimdPolicy,
    /// CSR maintenance mode for the coordinator's update path
    /// (`graph::dyncsr`): `Auto` (the default) maintains G/Gᵀ incrementally
    /// in O(batch) unless the `PAGERANK_CSR=rebuild` environment pin forces
    /// the legacy per-update full rebuild + transpose; `Rebuild`/
    /// `Incremental` override the environment. Ranks are bitwise identical
    /// in both modes (sorted-row contract); only maintenance cost changes.
    pub csr_mode: CsrMode,
}

impl Default for PagerankConfig {
    fn default() -> Self {
        Self {
            alpha: 0.85,
            tau: 1e-10,
            tau_frontier: 1e-6,
            tau_prune: 1e-6,
            max_iterations: 500,
            threads: 0,
            pool_persistent: true,
            simd: SimdPolicy::Auto,
            csr_mode: CsrMode::Auto,
        }
    }
}

impl PagerankConfig {
    /// The reference configuration of Section 5.1.5: an unreachably small
    /// tolerance so the run is capped by `max_iterations` (500).
    pub fn reference() -> Self {
        Self { tau: 1e-100, ..Self::default() }
    }

    /// This configuration with an explicit native-pool thread count.
    pub fn with_threads(self, threads: usize) -> Self {
        Self { threads, ..self }
    }

    /// This configuration with the persistent stealing pool enabled
    /// (`true`, the default) or the legacy per-region spawn path (`false`).
    pub fn with_pool_persistent(self, pool_persistent: bool) -> Self {
        Self { pool_persistent, ..self }
    }

    /// This configuration with an explicit SIMD backend policy.
    pub fn with_simd(self, simd: SimdPolicy) -> Self {
        Self { simd, ..self }
    }

    /// This configuration with an explicit CSR maintenance mode.
    pub fn with_csr_mode(self, csr_mode: CsrMode) -> Self {
        Self { csr_mode, ..self }
    }

    /// Check every field for values no engine can run with (NaN tolerances,
    /// α outside (0, 1), a zero iteration cap). Returns the first violation.
    pub fn validate(&self) -> Result<(), ConfigError> {
        if !self.alpha.is_finite() || self.alpha <= 0.0 || self.alpha >= 1.0 {
            return Err(ConfigError::Alpha(self.alpha));
        }
        for (name, v) in [
            ("tau", self.tau),
            ("tau_frontier", self.tau_frontier),
            ("tau_prune", self.tau_prune),
        ] {
            if !v.is_finite() || v < 0.0 {
                return Err(ConfigError::Tolerance(name, v));
            }
        }
        if self.max_iterations == 0 {
            return Err(ConfigError::ZeroIterations);
        }
        Ok(())
    }

    /// A valid configuration derived from this one by clamping each bad
    /// field to its paper default. The coordinator sanitizes untrusted
    /// configs at construction so no later engine run can divide by zero or
    /// spin forever; callers who want the typed diagnosis use [`validate`].
    ///
    /// [`validate`]: PagerankConfig::validate
    pub fn sanitized(self) -> Self {
        let d = Self::default();
        let tol = |v: f64, d: f64| if v.is_finite() && v >= 0.0 { v } else { d };
        Self {
            alpha: if self.alpha.is_finite() && self.alpha > 0.0 && self.alpha < 1.0 {
                self.alpha
            } else {
                d.alpha
            },
            tau: tol(self.tau, d.tau),
            tau_frontier: tol(self.tau_frontier, d.tau_frontier),
            tau_prune: tol(self.tau_prune, d.tau_prune),
            max_iterations: if self.max_iterations == 0 {
                d.max_iterations
            } else {
                self.max_iterations
            },
            threads: self.threads,
            pool_persistent: self.pool_persistent,
            simd: self.simd,
            csr_mode: self.csr_mode,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = PagerankConfig::default();
        assert_eq!(c.alpha, 0.85);
        assert_eq!(c.tau, 1e-10);
        assert_eq!(c.tau_frontier, 1e-6);
        assert_eq!(c.tau_prune, 1e-6);
        assert_eq!(c.max_iterations, 500);
        assert_eq!(c.threads, 0, "0 = use available parallelism");
        assert!(c.pool_persistent, "persistent stealing pool is the default");
        assert_eq!(c.simd, SimdPolicy::Auto, "SIMD auto-detect is the default");
        assert_eq!(c.csr_mode, CsrMode::Auto, "incremental CSR is the default");
        assert!(crate::util::par::resolve(c.threads) >= 1);
    }

    #[test]
    fn with_threads_builder() {
        let c = PagerankConfig::default().with_threads(4);
        assert_eq!(c.threads, 4);
        assert_eq!(c.alpha, 0.85);
        let c = c.with_pool_persistent(false);
        assert!(!c.pool_persistent);
        assert_eq!(c.threads, 4, "other fields untouched");
        let c = c.with_simd(SimdPolicy::Scalar);
        assert_eq!(c.simd, SimdPolicy::Scalar);
        assert_eq!(c.threads, 4, "other fields untouched");
        let c = c.with_csr_mode(CsrMode::Rebuild);
        assert_eq!(c.csr_mode, CsrMode::Rebuild);
        assert_eq!(c.simd, SimdPolicy::Scalar, "other fields untouched");
    }

    #[test]
    fn validate_catches_each_field() {
        assert!(PagerankConfig::default().validate().is_ok());
        assert!(PagerankConfig::reference().validate().is_ok());
        let bad_alpha = PagerankConfig { alpha: 1.5, ..Default::default() };
        assert_eq!(bad_alpha.validate(), Err(ConfigError::Alpha(1.5)));
        let nan_tau = PagerankConfig { tau: f64::NAN, ..Default::default() };
        assert!(matches!(nan_tau.validate(), Err(ConfigError::Tolerance("tau", _))));
        let neg_tf = PagerankConfig { tau_frontier: -1.0, ..Default::default() };
        assert!(matches!(
            neg_tf.validate(),
            Err(ConfigError::Tolerance("tau_frontier", _))
        ));
        let zero_it = PagerankConfig { max_iterations: 0, ..Default::default() };
        assert_eq!(zero_it.validate(), Err(ConfigError::ZeroIterations));
    }

    #[test]
    fn sanitized_clamps_only_bad_fields() {
        let c = PagerankConfig {
            alpha: f64::NAN,
            tau: -3.0,
            tau_frontier: 1e-5,
            tau_prune: f64::INFINITY,
            max_iterations: 0,
            threads: 3,
            pool_persistent: false,
            simd: SimdPolicy::Vector,
            csr_mode: CsrMode::Rebuild,
        }
        .sanitized();
        assert!(c.validate().is_ok());
        assert_eq!(c.alpha, 0.85);
        assert_eq!(c.tau, 1e-10);
        assert_eq!(c.tau_frontier, 1e-5, "good field kept");
        assert_eq!(c.tau_prune, 1e-6);
        assert_eq!(c.max_iterations, 500);
        assert_eq!(c.threads, 3);
        assert!(!c.pool_persistent, "mode knob passes through untouched");
        assert_eq!(c.simd, SimdPolicy::Vector, "simd knob passes through untouched");
        assert_eq!(c.csr_mode, CsrMode::Rebuild, "csr knob passes through untouched");
        let good = PagerankConfig::default().with_threads(2);
        assert_eq!(good.sanitized(), good, "valid config untouched");
    }
}
