//! Asynchronous (in-place) PageRank variants — the paper's Section 4.2
//! ablation: on multicore CPUs the authors observe *asynchronous* iteration
//! (a single rank vector, updates visible immediately) converges in fewer
//! iterations and runs faster, while on the GPU the synchronous two-vector
//! scheme wins; our native engines default to synchronous for parity with
//! the device engines, and this module provides the asynchronous
//! counterparts for the ablation bench (EXPERIMENTS.md §Perf).

use std::time::Instant;

use super::affected::{expand_affected, initial_affected};
use crate::batch::BatchUpdate;
use crate::engines::config::PagerankConfig;
use crate::engines::PagerankResult;
use crate::graph::CsrGraph;
use crate::util::simd;

/// Asynchronous Static PageRank: one rank vector, Gauss-Seidel-style sweeps
/// (each vertex pulls whatever mix of old/new neighbor ranks exists).
pub fn static_async(
    g: &CsrGraph,
    gt: &CsrGraph,
    cfg: &PagerankConfig,
    r0: Option<&[f64]>,
) -> PagerankResult {
    let n = g.num_vertices();
    let start = Instant::now();
    let be = simd::resolve(cfg.simd);
    // out-degrees as f64, computed once per solve: the sweep's fused
    // contribution pull becomes a striped gather-divide (`util::simd`),
    // reading whatever mix of old/new ranks currently sits in `r`.
    let degf = g.degrees_f64();
    let mut r: Vec<f64> = match r0 {
        Some(prev) => prev.to_vec(),
        None => vec![1.0 / n as f64; n],
    };
    let c0 = (1.0 - cfg.alpha) / n as f64;

    let mut iterations = 0;
    for _ in 0..cfg.max_iterations {
        let mut linf = 0.0f64;
        for v in 0..n as u32 {
            let c = simd::gather_div_sum(be, &r, &degf, gt.neighbors(v));
            let nr = c0 + cfg.alpha * c;
            linf = linf.max((nr - r[v as usize]).abs());
            r[v as usize] = nr; // immediately visible to later vertices
        }
        iterations += 1;
        if linf <= cfg.tau {
            break;
        }
    }
    PagerankResult::new(r, iterations, start.elapsed())
}

/// Asynchronous DF-P (the configuration the paper's CPU implementation
/// [49] prefers): in-place rank updates + frontier expansion/pruning.
pub fn dynamic_frontier_async(
    g: &CsrGraph,
    gt: &CsrGraph,
    cfg: &PagerankConfig,
    prev: &[f64],
    batch: &BatchUpdate,
    prune: bool,
) -> PagerankResult {
    let n = g.num_vertices();
    let start = Instant::now();
    let be = simd::resolve(cfg.simd);
    let degf = g.degrees_f64();
    let (mut dv, mut dn) = initial_affected(n, batch);
    expand_affected(&mut dv, &dn, g);
    let initially_affected = dv.iter().filter(|&&x| x != 0).count();

    let mut r = prev.to_vec();
    let c0 = (1.0 - cfg.alpha) / n as f64;

    let mut iterations = 0;
    for _ in 0..cfg.max_iterations {
        dn.iter_mut().for_each(|x| *x = 0);
        let mut linf = 0.0f64;
        for v in 0..n {
            if dv[v] == 0 {
                continue;
            }
            let c = simd::gather_div_sum(be, &r, &degf, gt.neighbors(v as u32));
            let d_v = degf[v];
            let nr = if prune {
                let k = c - r[v] / d_v;
                (cfg.alpha * k + c0) / (1.0 - cfg.alpha / d_v)
            } else {
                c0 + cfg.alpha * c
            };
            let delta = (nr - r[v]).abs();
            let denom = nr.max(r[v]);
            let rel = if denom > 0.0 { delta / denom } else { 0.0 };
            if prune && rel <= cfg.tau_prune {
                dv[v] = 0;
            }
            if rel > cfg.tau_frontier {
                dn[v] = 1;
            }
            r[v] = nr;
            linf = linf.max(delta);
        }
        iterations += 1;
        if linf <= cfg.tau {
            break;
        }
        expand_affected(&mut dv, &dn, g);
    }
    PagerankResult { ranks: r, iterations, elapsed: start.elapsed(), initially_affected }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batch;
    use crate::engines::error::l1_distance;
    use crate::engines::native::static_pagerank;
    use crate::generators::er;

    #[test]
    fn async_static_matches_sync_fixed_point() {
        let g = er::generate(400, 5.0, 2).to_csr();
        let gt = g.transpose();
        let cfg = PagerankConfig::default();
        let sync = static_pagerank(&g, &gt, &cfg, None);
        let asyn = static_async(&g, &gt, &cfg, None);
        assert!(l1_distance(&sync.ranks, &asyn.ranks).unwrap() < 1e-7);
    }

    #[test]
    fn async_iteration_count_comparable() {
        // the paper's CPU observation is a wallclock win; iteration counts
        // land in the same band (in-place updates propagate faster within a
        // sweep but the L-inf stopping rule sees mid-sweep mixtures), so we
        // assert the counts stay within 20% of each other.
        let g = er::generate(600, 5.0, 4).to_csr();
        let gt = g.transpose();
        let cfg = PagerankConfig::default();
        let sync = static_pagerank(&g, &gt, &cfg, None);
        let asyn = static_async(&g, &gt, &cfg, None);
        let hi = sync.iterations + sync.iterations / 5;
        assert!(
            asyn.iterations <= hi,
            "async {} vs sync {}",
            asyn.iterations,
            sync.iterations
        );
    }

    #[test]
    fn async_backends_bitwise_identical() {
        use crate::util::SimdPolicy;
        let g = er::generate(300, 4.0, 8).to_csr();
        let gt = g.transpose();
        let cfg = PagerankConfig::default();
        let scalar = static_async(&g, &gt, &cfg.with_simd(SimdPolicy::Scalar), None);
        let vector = static_async(&g, &gt, &cfg.with_simd(SimdPolicy::Vector), None);
        assert_eq!(scalar.iterations, vector.iterations);
        for (a, b) in scalar.ranks.iter().zip(&vector.ranks) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn async_dfp_tracks_reference() {
        let mut b = er::generate(350, 5.0, 6);
        let g0 = b.to_csr();
        let gt0 = g0.transpose();
        let cfg = PagerankConfig::default();
        let prev = static_pagerank(&g0, &gt0, &cfg, None).ranks;
        let upd = batch::random_batch(&b, 6, 0.8, 9);
        batch::apply(&mut b, &upd);
        let g = b.to_csr();
        let gt = g.transpose();
        let truth = static_pagerank(&g, &gt, &cfg, None).ranks;
        for prune in [false, true] {
            let res = dynamic_frontier_async(&g, &gt, &cfg, &prev, &upd, prune);
            let err = l1_distance(&res.ranks, &truth).unwrap();
            assert!(err < 1e-2, "prune={prune}: {err}");
            assert!(res.initially_affected > 0);
        }
    }
}
