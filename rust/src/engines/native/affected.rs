//! Affected-vertex marking (paper Algorithm 5 + the DT approach's BFS).

use crate::batch::BatchUpdate;
use crate::graph::CsrGraph;

/// Algorithm 5 `initialAffected`: for each deletion (u,v), u's out-neighbors
/// will be marked (δ_N[u]=1) and the target v is marked directly (δ_V[v]=1);
/// for each insertion (u,v), u's out-neighbors will be marked. Returns
/// (δ_V, δ_N) as u8 flags (the paper stores affected flags in 8-bit ints).
pub fn initial_affected(n: usize, batch: &BatchUpdate) -> (Vec<u8>, Vec<u8>) {
    let mut dv = vec![0u8; n];
    let mut dn = vec![0u8; n];
    for &(u, v) in &batch.deletions {
        dn[u as usize] = 1;
        dv[v as usize] = 1;
    }
    for &(u, _v) in &batch.insertions {
        dn[u as usize] = 1;
    }
    (dv, dn)
}

/// Algorithm 5 `expandAffected`: mark out-neighbors of every vertex with
/// δ_N set. Sequential here (the native engines call it on small frontiers;
/// the device engines run the partitioned kernel instead).
pub fn expand_affected(dv: &mut [u8], dn: &[u8], g: &CsrGraph) {
    for u in 0..g.num_vertices() as u32 {
        if dn[u as usize] != 0 {
            for &v in g.neighbors(u) {
                dv[v as usize] = 1;
            }
        }
    }
}

/// The Dynamic Traversal approach's marking: flag everything reachable from
/// the source vertex of each update, in either the old or new graph
/// (Desikan et al.; paper Section 3.4.2). Plain BFS over both snapshots.
pub fn dt_affected(g_new: &CsrGraph, g_old: &CsrGraph, batch: &BatchUpdate) -> Vec<u8> {
    let n = g_new.num_vertices();
    let mut aff = vec![0u8; n];
    let mut queue: Vec<u32> = Vec::new();
    for &(u, _) in batch.deletions.iter().chain(&batch.insertions) {
        if aff[u as usize] == 0 {
            aff[u as usize] = 1;
            queue.push(u);
        }
    }
    let mut head = 0;
    while head < queue.len() {
        let u = queue[head];
        head += 1;
        let both = g_new
            .neighbors(u)
            .iter()
            .chain(if (u as usize) < g_old.num_vertices() {
                g_old.neighbors(u).iter()
            } else {
                [].iter()
            });
        for &v in both {
            if aff[v as usize] == 0 {
                aff[v as usize] = 1;
                queue.push(v);
            }
        }
    }
    aff
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    fn line_graph(n: usize) -> CsrGraph {
        let mut b = GraphBuilder::new(n);
        for v in 0..n - 1 {
            b.insert_edge(v as u32, (v + 1) as u32);
        }
        b.ensure_self_loops();
        b.to_csr()
    }

    #[test]
    fn initial_marks_per_algorithm5() {
        let batch = BatchUpdate {
            deletions: vec![(1, 2)],
            insertions: vec![(3, 4)],
        };
        let (dv, dn) = initial_affected(6, &batch);
        assert_eq!(dv, vec![0, 0, 1, 0, 0, 0]); // deletion target
        assert_eq!(dn, vec![0, 1, 0, 1, 0, 0]); // both sources
    }

    #[test]
    fn expand_marks_out_neighbors() {
        let g = line_graph(5);
        let mut dv = vec![0u8; 5];
        let dn = vec![0, 1, 0, 0, 0];
        expand_affected(&mut dv, &dn, &g);
        // vertex 1's out-neighbors: itself (self-loop) and 2
        assert_eq!(dv, vec![0, 1, 1, 0, 0]);
    }

    #[test]
    fn dt_marks_reachable_suffix() {
        let g = line_graph(6);
        let batch = BatchUpdate { deletions: vec![], insertions: vec![(2, 3)] };
        let aff = dt_affected(&g, &g, &batch);
        assert_eq!(aff, vec![0, 0, 1, 1, 1, 1]); // everything from 2 onward
    }

    #[test]
    fn dt_uses_old_graph_too() {
        // old graph has edge 0 -> 5 that the new one lacks
        let mut b_old = GraphBuilder::new(6);
        b_old.insert_edge(0, 5);
        b_old.ensure_self_loops();
        let g_old = b_old.to_csr();
        let mut b_new = GraphBuilder::new(6);
        b_new.ensure_self_loops();
        let g_new = b_new.to_csr();
        let batch = BatchUpdate { deletions: vec![(0, 5)], insertions: vec![] };
        let aff = dt_affected(&g_new, &g_old, &batch);
        assert_eq!(aff[5], 1, "reachable in the old graph");
    }
}
