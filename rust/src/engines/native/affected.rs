//! Affected-vertex marking (paper Algorithm 5 + the DT approach's BFS).
//!
//! `expandAffected` is the one push-direction kernel in the native engines:
//! out-neighbors of every δ_N vertex get δ_V set. The parallel variant
//! partitions the graph's *edge array* into fixed [`EXPAND_EDGE_BLOCK`]-sized
//! ranges (out-degree partitioning: a hub's out-edges span many blocks and
//! are pushed by many lanes) and runs them as tasks on the work-stealing
//! pool, every lane marking directly into a shared `AtomicU8` view of δ_V.
//! The only store is an idempotent `1`, so the final flag set is the OR of
//! the per-range marks regardless of which worker runs (or steals) a range
//! — the result is independent of thread count and schedule, and the
//! skewed hub ranges that used to load-imbalance a static round-robin
//! assignment are simply stolen by idle lanes.
//!
//! This module is all-integer (u8 flags, edge ranges — no floating point),
//! so it is independent of the `util::simd` backend by construction: the
//! scalar×SIMD equivalence matrix needs no expansion-side cases.

use std::sync::atomic::{AtomicU8, Ordering};

use crate::batch::BatchUpdate;
use crate::graph::CsrGraph;
use crate::util::par;

/// Fixed edge-range granularity for the parallel push (independent of the
/// thread count, so the work decomposition is reproducible).
pub(crate) const EXPAND_EDGE_BLOCK: usize = 8192;

/// Below this many edges the region submission costs more than the push
/// itself; run the sequential loop.
const EXPAND_PAR_CUTOFF: usize = 1 << 14;

/// Algorithm 5 `initialAffected`: for each deletion (u,v), u's out-neighbors
/// will be marked (δ_N[u]=1) and the target v is marked directly (δ_V[v]=1);
/// for each insertion (u,v), u's out-neighbors will be marked. Returns
/// (δ_V, δ_N) as u8 flags (the paper stores affected flags in 8-bit ints).
pub fn initial_affected(n: usize, batch: &BatchUpdate) -> (Vec<u8>, Vec<u8>) {
    let mut dv = vec![0u8; n];
    let mut dn = vec![0u8; n];
    for &(u, v) in &batch.deletions {
        dn[u as usize] = 1;
        dv[v as usize] = 1;
    }
    for &(u, _v) in &batch.insertions {
        dn[u as usize] = 1;
    }
    (dv, dn)
}

/// Algorithm 5 `expandAffected`, sequential: mark out-neighbors of every
/// vertex with δ_N set. Reference semantics for [`expand_affected_threads`].
pub fn expand_affected(dv: &mut [u8], dn: &[u8], g: &CsrGraph) {
    for u in 0..g.num_vertices() as u32 {
        if dn[u as usize] != 0 {
            for &v in g.neighbors(u) {
                dv[v as usize] = 1;
            }
        }
    }
}

/// Algorithm 5 `expandAffected` on the work-stealing pool. Bit-identical to
/// [`expand_affected`] at every `threads` setting and steal schedule: the
/// fixed edge ranges depend only on the graph, pre-set δ_V flags are never
/// cleared, and the only concurrent store is an idempotent relaxed `1` into
/// a shared atomic view of δ_V. Falls back to the sequential loop for one
/// thread or small graphs.
pub fn expand_affected_threads(dv: &mut [u8], dn: &[u8], g: &CsrGraph, threads: usize) {
    let threads = par::resolve(threads);
    let m = g.num_edges();
    if threads == 1 || m < EXPAND_PAR_CUTOFF {
        expand_affected(dv, dn, g);
        return;
    }

    // SAFETY: AtomicU8 has the same in-memory representation as u8, and the
    // exclusive borrow of `dv` is held for the whole region — reinterpreting
    // it as a shared atomic view is sound, and the pool's completion barrier
    // orders every mark before the caller reads `dv` again.
    let flags: &[AtomicU8] = unsafe { &*(dv as *mut [u8] as *const [AtomicU8]) };

    if !g.is_packed() {
        // Slack layout: offsets are not monotone after row relocations, so
        // the edge-array partition below doesn't apply. Partition by vertex
        // instead — marks are idempotent `1` stores, so any decomposition
        // yields the same final flag set.
        let n = g.num_vertices();
        par::par_for_index(threads, par::DEFAULT_BLOCK, n, |lo, hi| {
            for u in lo..hi {
                if dn[u] != 0 {
                    for &v in g.neighbors(u as u32) {
                        flags[v as usize].store(1, Ordering::Relaxed);
                    }
                }
            }
        });
        return;
    }
    let offsets = g.offsets();
    let targets = g.targets();

    par::par_for_index(threads, EXPAND_EDGE_BLOCK, m, |lo, hi| {
        // last row whose edge range starts at or before lo
        let mut row = offsets.partition_point(|&o| (o as usize) <= lo) - 1;
        let mut idx = lo;
        while idx < hi {
            let row_end = (offsets[row + 1] as usize).min(hi);
            if dn[row] != 0 {
                for &v in &targets[idx..row_end] {
                    flags[v as usize].store(1, Ordering::Relaxed);
                }
            }
            idx = row_end;
            row += 1;
        }
    });
}

/// The Dynamic Traversal approach's marking: flag everything reachable from
/// the source vertex of each update, in either the old or new graph
/// (Desikan et al.; paper Section 3.4.2). Plain BFS over both snapshots.
pub fn dt_affected(g_new: &CsrGraph, g_old: &CsrGraph, batch: &BatchUpdate) -> Vec<u8> {
    let n = g_new.num_vertices();
    let mut aff = vec![0u8; n];
    let mut queue: Vec<u32> = Vec::new();
    for &(u, _) in batch.deletions.iter().chain(&batch.insertions) {
        if aff[u as usize] == 0 {
            aff[u as usize] = 1;
            queue.push(u);
        }
    }
    let mut head = 0;
    while head < queue.len() {
        let u = queue[head];
        head += 1;
        let both = g_new
            .neighbors(u)
            .iter()
            .chain(if (u as usize) < g_old.num_vertices() {
                g_old.neighbors(u).iter()
            } else {
                [].iter()
            });
        for &v in both {
            if aff[v as usize] == 0 {
                aff[v as usize] = 1;
                queue.push(v);
            }
        }
    }
    aff
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::util::Rng;

    fn line_graph(n: usize) -> CsrGraph {
        let mut b = GraphBuilder::new(n);
        for v in 0..n - 1 {
            b.insert_edge(v as u32, (v + 1) as u32);
        }
        b.ensure_self_loops();
        b.to_csr()
    }

    #[test]
    fn initial_marks_per_algorithm5() {
        let batch = BatchUpdate {
            deletions: vec![(1, 2)],
            insertions: vec![(3, 4)],
        };
        let (dv, dn) = initial_affected(6, &batch);
        assert_eq!(dv, vec![0, 0, 1, 0, 0, 0]); // deletion target
        assert_eq!(dn, vec![0, 1, 0, 1, 0, 0]); // both sources
    }

    #[test]
    fn expand_marks_out_neighbors() {
        let g = line_graph(5);
        let mut dv = vec![0u8; 5];
        let dn = vec![0, 1, 0, 0, 0];
        expand_affected(&mut dv, &dn, &g);
        // vertex 1's out-neighbors: itself (self-loop) and 2
        assert_eq!(dv, vec![0, 1, 1, 0, 0]);
    }

    #[test]
    fn parallel_expand_matches_sequential_on_hub_graph() {
        // star with a high out-degree hub: its edge range spans many blocks,
        // so many threads push the same frontier vertex's neighbors — the
        // regression shape for the OR-merge (a shared-buffer version races
        // here and historically dropped flags)
        let n = 60_000usize;
        let mut edges: Vec<(u32, u32)> = Vec::new();
        for v in 0..n as u32 {
            edges.push((0, v)); // hub 0 points everywhere
            edges.push((v, (v + 1) % n as u32));
        }
        let g = CsrGraph::from_edges(n, &edges);
        let mut rng = Rng::seed_from_u64(42);
        let mut dn = vec![0u8; n];
        dn[0] = 1; // the hub is in the frontier
        for _ in 0..200 {
            dn[(rng.next_u64() % n as u64) as usize] = 1;
        }
        let mut want = vec![0u8; n];
        expand_affected(&mut want, &dn, &g);
        for threads in [2, 3, 4, 8] {
            let mut got = vec![0u8; n];
            // pre-set flags must survive the merge
            got[n - 1] = 1;
            let mut want_t = want.clone();
            want_t[n - 1] = 1;
            expand_affected_threads(&mut got, &dn, &g, threads);
            assert_eq!(got, want_t, "threads={threads}");
        }
    }

    #[test]
    fn parallel_expand_small_graph_falls_back() {
        let g = line_graph(5);
        let mut dv = vec![0u8; 5];
        let dn = vec![0, 1, 0, 0, 0];
        expand_affected_threads(&mut dv, &dn, &g, 4);
        assert_eq!(dv, vec![0, 1, 1, 0, 0]);
    }

    #[test]
    fn dt_marks_reachable_suffix() {
        let g = line_graph(6);
        let batch = BatchUpdate { deletions: vec![], insertions: vec![(2, 3)] };
        let aff = dt_affected(&g, &g, &batch);
        assert_eq!(aff, vec![0, 0, 1, 1, 1, 1]); // everything from 2 onward
    }

    #[test]
    fn dt_uses_old_graph_too() {
        // old graph has edge 0 -> 5 that the new one lacks
        let mut b_old = GraphBuilder::new(6);
        b_old.insert_edge(0, 5);
        b_old.ensure_self_loops();
        let g_old = b_old.to_csr();
        let mut b_new = GraphBuilder::new(6);
        b_new.ensure_self_loops();
        let g_new = b_new.to_csr();
        let batch = BatchUpdate { deletions: vec![(0, 5)], insertions: vec![] };
        let aff = dt_affected(&g_new, &g_old, &batch);
        assert_eq!(aff[5], 1, "reachable in the old graph");
    }
}
