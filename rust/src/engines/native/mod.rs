//! Native (multicore CPU) engines — the paper's parallel CPU comparator
//! [49], used directly for the GPU-vs-CPU comparisons (Figures 6-8) and as
//! the fallback for graphs larger than the biggest device tier.
//!
//! All five approaches use the same synchronous pull-based formulation as
//! the device engines: two rank vectors, one write per vertex per
//! iteration, L∞ convergence detection.

pub mod affected;
pub mod asynchronous;
pub mod dynamic;

use std::time::Instant;

use super::config::PagerankConfig;
use super::PagerankResult;
use crate::graph::CsrGraph;

/// c[v] = Σ_{u ∈ G.in(v)} r[u]/outdeg(u) for one vertex, pulled over the
/// transpose adjacency.
#[inline]
pub(crate) fn pull_contrib(gt: &CsrGraph, contrib: &[f64], v: u32) -> f64 {
    gt.neighbors(v).iter().map(|&u| contrib[u as usize]).sum()
}

/// One synchronous iteration of Eq. 1 over all vertices. Returns the L∞
/// delta. `contrib[u]` must hold `r[u]/outdeg(u)`.
fn step_plain(
    gt: &CsrGraph,
    contrib: &[f64],
    r: &[f64],
    r_new: &mut [f64],
    c0: f64,
    alpha: f64,
) -> f64 {
    let mut linf = 0.0f64;
    for (v, out) in r_new.iter_mut().enumerate() {
        let c = pull_contrib(gt, contrib, v as u32);
        let nr = c0 + alpha * c;
        linf = linf.max((nr - r[v]).abs());
        *out = nr;
    }
    linf
}

/// Static PageRank (Algorithm 1): cold start from 1/|V|, or warm start from
/// `r0` (the Naive-dynamic approach — identical loop, different init).
pub fn static_pagerank(
    g: &CsrGraph,
    gt: &CsrGraph,
    cfg: &PagerankConfig,
    r0: Option<&[f64]>,
) -> PagerankResult {
    let n = g.num_vertices();
    debug_assert!(g.has_no_dead_ends());
    let start = Instant::now();

    let mut r: Vec<f64> = match r0 {
        Some(prev) => prev.to_vec(),
        None => vec![1.0 / n as f64; n],
    };
    let mut r_new = vec![0.0f64; n];
    let mut contrib = vec![0.0f64; n];
    let c0 = (1.0 - cfg.alpha) / n as f64;

    let mut iterations = 0;
    for _ in 0..cfg.max_iterations {
        for (u, c) in contrib.iter_mut().enumerate() {
            *c = r[u] / g.degree(u as u32) as f64;
        }
        let linf = step_plain(gt, &contrib, &r, &mut r_new, c0, cfg.alpha);
        std::mem::swap(&mut r, &mut r_new);
        iterations += 1;
        if linf <= cfg.tau {
            break;
        }
    }
    PagerankResult::new(r, iterations, start.elapsed())
}

/// Naive-dynamic: warm start from the previous snapshot's ranks.
pub fn naive_dynamic(
    g: &CsrGraph,
    gt: &CsrGraph,
    cfg: &PagerankConfig,
    prev: &[f64],
) -> PagerankResult {
    static_pagerank(g, gt, cfg, Some(prev))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generators::er;

    fn ranks_sum_to_one(r: &[f64]) -> bool {
        (r.iter().sum::<f64>() - 1.0).abs() < 1e-6
    }

    #[test]
    fn static_converges_on_ring() {
        // symmetric ring: uniform ranks
        let n = 10;
        let mut adj: Vec<Vec<u32>> = (0..n)
            .map(|v| vec![v as u32, ((v + 1) % n) as u32])
            .collect();
        adj[0].sort_unstable();
        let g = CsrGraph::from_adjacency(&adj);
        let gt = g.transpose();
        let res = static_pagerank(&g, &gt, &PagerankConfig::default(), None);
        assert!(res.iterations < 100);
        for &x in &res.ranks {
            assert!((x - 0.1).abs() < 1e-9, "{x}");
        }
    }

    #[test]
    fn static_sums_to_one_random() {
        let g = er::generate(500, 5.0, 3).to_csr();
        let gt = g.transpose();
        let res = static_pagerank(&g, &gt, &PagerankConfig::default(), None);
        assert!(ranks_sum_to_one(&res.ranks));
        assert!(res.iterations > 5 && res.iterations < 200);
    }

    #[test]
    fn warm_start_converges_faster() {
        let g = er::generate(800, 5.0, 7).to_csr();
        let gt = g.transpose();
        let cfg = PagerankConfig::default();
        let cold = static_pagerank(&g, &gt, &cfg, None);
        let warm = static_pagerank(&g, &gt, &cfg, Some(&cold.ranks));
        assert!(warm.iterations <= 2, "warm restart on same graph: {}", warm.iterations);
    }

    #[test]
    fn higher_indegree_higher_rank() {
        // star: everyone points at 0
        let n = 20usize;
        let mut adj: Vec<Vec<u32>> = (0..n).map(|v| vec![v as u32]).collect();
        for v in 1..n {
            adj[v].push(0);
        }
        let g = CsrGraph::from_adjacency(&adj);
        let gt = g.transpose();
        let res = static_pagerank(&g, &gt, &PagerankConfig::default(), None);
        assert!(res.ranks[0] > res.ranks[1] * 5.0);
    }
}
