//! Native multicore CPU engines — the paper's parallel CPU comparator
//! [49], used directly for the GPU-vs-CPU comparisons (Figures 6-8) and as
//! the fallback for graphs larger than the biggest device tier.
//!
//! All five approaches use the same synchronous pull-based formulation as
//! the device engines: two rank vectors, one write per vertex per
//! iteration, L∞ convergence detection. Iterations run on the persistent
//! work-stealing pool (`util::par`, lane count from
//! [`PagerankConfig::threads`], strategy from
//! [`PagerankConfig::pool_persistent`]) with the paper's two-kernel degree
//! split (Algorithm 4 via `graph::partition::partition_by_degree`):
//!
//! * **low in-degree** vertices are chunked across lanes in fixed vertex
//!   blocks, each vertex's in-neighbor sum a striped lane-tree gather;
//! * **hub** vertices (in-degree > [`HUB_IN_DEGREE`]) get partial sums
//!   over *fixed* [`HUB_EDGE_CHUNK`]-sized in-edge ranges, combined in
//!   fixed chunk order — a lane that finishes its dealt chunks steals the
//!   rest, so skewed hub distributions no longer serialize the step.
//!
//! Because the blocking is a function of the graph only — never of the
//! thread count or the steal schedule — and every partial lands in a
//! chunk-indexed slot reduced in fixed order, ranks are bit-identical at
//! every `threads` setting, and `threads = 1` runs the same loops inline
//! (no atomics anywhere on the rank path).
//!
//! The memory-bound inner loops — the contribution scaling pass, the pull
//! gathers (both the low-degree per-vertex sums and the hub edge chunks)
//! and the dangling-mass sum — run through `util::simd`: runtime-dispatched
//! AVX2 lanes with a portable 4-lane fallback, both obeying the same fixed
//! lane-tree reduction order, so ranks are additionally bit-identical
//! between the scalar and vector backends ([`PagerankConfig::simd`] /
//! `PAGERANK_SIMD=0` select the scalar reference path).
//!
//! Dead ends: a vertex with no out-edges would divide by zero in the
//! contribution pass (the paper sidesteps this by inserting self-loops at
//! load time). The engines instead apply the standard teleport fallback: a
//! dead end contributes `0` along edges and its rank mass is redistributed
//! uniformly (`α·dangling/n` joins the teleport constant). On self-looped
//! graphs the dangling mass is exactly `0.0` and the update is bit-for-bit
//! the paper's Eq. 1.

pub mod affected;
pub mod asynchronous;
pub mod dynamic;

use std::time::Instant;

use super::config::PagerankConfig;
use super::PagerankResult;
use crate::graph::{partition_by_degree, CsrGraph};
use crate::util::par;
use crate::util::simd::{self, Backend};

/// In-degree above which a vertex takes the hub (edge-chunked) path. Shared
/// with `graph::dyncsr`, which maintains the hub list incrementally at this
/// exact threshold so `StepPlan::build` can skip the degree scan.
pub(crate) const HUB_IN_DEGREE: u32 = crate::graph::dyncsr::HUB_DEGREE_THRESHOLD;

/// Fixed in-edge chunk size for hub partial sums. Independent of the thread
/// count, so the summation tree — and hence the floating-point result — is
/// identical at every `threads` setting.
pub(crate) const HUB_EDGE_CHUNK: usize = 4096;

/// c[v] = Σ_{u ∈ G.in(v)} r[u]/outdeg(u) for one vertex, pulled over the
/// transpose adjacency as a striped lane-tree gather (`util::simd`).
#[inline]
pub(crate) fn pull_contrib(be: Backend, gt: &CsrGraph, contrib: &[f64], v: u32) -> f64 {
    simd::gather_sum(be, contrib, gt.neighbors(v))
}

/// Degree-partitioned schedule for the pull step over `gt`, built once per
/// run (Algorithm 4): the hub list plus a fixed decomposition of every
/// hub's in-edge range into [`HUB_EDGE_CHUNK`]-sized work items.
pub(crate) struct StepPlan {
    /// Resolved pool width.
    pub threads: usize,
    /// Resolved SIMD backend for every gather in this run.
    pub backend: Backend,
    /// High in-degree vertices, in `partition_by_degree` (ascending) order.
    pub hubs: Vec<u32>,
    /// (index into `hubs`, absolute edge range in `gt.targets()`).
    items: Vec<(u32, usize, usize)>,
    /// `items[item_start[h]..item_start[h+1]]` belong to `hubs[h]`.
    item_start: Vec<usize>,
}

impl StepPlan {
    pub(crate) fn build(gt: &CsrGraph, threads: usize, backend: Backend) -> StepPlan {
        let threads = par::resolve(threads);
        // Prefer the incrementally-maintained hub cache (graph::dyncsr);
        // fall back to the Algorithm-4 partition scan. Both produce the
        // high-degree vertices in ascending id order.
        let hubs: Vec<u32> = match gt.cached_hubs(HUB_IN_DEGREE) {
            Some(cached) => {
                debug_assert_eq!(
                    cached,
                    partition_by_degree(&gt.degrees(), HUB_IN_DEGREE).high(),
                    "stale hub cache"
                );
                cached.to_vec()
            }
            None => partition_by_degree(&gt.degrees(), HUB_IN_DEGREE).high().to_vec(),
        };
        let mut items = Vec::new();
        let mut item_start = Vec::with_capacity(hubs.len() + 1);
        item_start.push(0);
        for (h, &v) in hubs.iter().enumerate() {
            // Chunk boundaries are relative to the row start, so packed and
            // slack layouts decompose a hub identically.
            let end = gt.row_end(v as usize);
            let mut lo = gt.row_start(v as usize);
            while lo < end {
                let hi = (lo + HUB_EDGE_CHUNK).min(end);
                items.push((h as u32, lo, hi));
                lo = hi;
            }
            item_start.push(items.len());
        }
        StepPlan { threads, backend, hubs, items, item_start }
    }

    /// Fold hub `h`'s chunk partials in fixed (chunk) order.
    pub(crate) fn hub_sum(&self, partials: &[f64], h: usize) -> f64 {
        partials[self.item_start[h]..self.item_start[h + 1]].iter().sum()
    }
}

/// Parallel partial sums for every hub in-edge chunk. With `active`, chunks
/// of inactive hubs are skipped (their partials stay `0.0`; callers must
/// not consume them). Chunk boundaries come from the plan, so the result is
/// thread-count invariant.
pub(crate) fn hub_partials(
    plan: &StepPlan,
    gt: &CsrGraph,
    contrib: &[f64],
    active: Option<&[u8]>,
) -> Vec<f64> {
    let mut partials = vec![0.0f64; plan.items.len()];
    let items = &plan.items;
    let hubs = &plan.hubs;
    let targets = gt.targets();
    let be = plan.backend;
    par::par_for(plan.threads, 1, &mut partials, |idx, slot| {
        let (h, lo, hi) = items[idx];
        if let Some(mask) = active {
            if mask[hubs[h as usize] as usize] == 0 {
                return;
            }
        }
        slot[0] = simd::gather_sum(be, contrib, &targets[lo..hi]);
    });
    partials
}

/// Fill `contrib[u] = r[u]/outdeg(u)` (0 for dead ends) on the pool and
/// return the dangling rank mass. Each block runs the striped
/// `simd::contrib_block` kernel; block partials fold in ascending block
/// order, so the result is thread-count *and* backend invariant (exactly
/// `0.0` when the graph has no dead ends).
pub(crate) fn compute_contrib(
    threads: usize,
    be: Backend,
    g: &CsrGraph,
    r: &[f64],
    contrib: &mut [f64],
) -> f64 {
    let (starts, ends) = g.row_bounds();
    par::par_reduce(
        threads,
        par::DEFAULT_BLOCK,
        contrib,
        0.0,
        |a, b| a + b,
        |start, out| simd::contrib_block(be, starts, ends, r, start, out),
    )
}

/// One synchronous iteration of Eq. 1 over all vertices, degree-partitioned
/// across the pool. Returns the L∞ delta. `contrib[u]` must hold
/// `r[u]/outdeg(u)`; `c0` may include the dangling teleport term.
pub(crate) fn step_plain(
    plan: &StepPlan,
    gt: &CsrGraph,
    contrib: &[f64],
    r: &[f64],
    r_new: &mut [f64],
    c0: f64,
    alpha: f64,
) -> f64 {
    // low in-degree vertices: blocked across threads, per-vertex striped
    // gathers (identical on every backend by the lane-tree contract)
    let mut linf = par::par_reduce(
        plan.threads,
        par::DEFAULT_BLOCK,
        r_new,
        0.0,
        f64::max,
        |start, out| {
            let mut lmax = 0.0f64;
            for (i, slot) in out.iter_mut().enumerate() {
                let v = (start + i) as u32;
                if gt.degree(v) > HUB_IN_DEGREE {
                    continue; // hub pass below owns this slot
                }
                let c = pull_contrib(plan.backend, gt, contrib, v);
                let nr = c0 + alpha * c;
                lmax = lmax.max((nr - r[start + i]).abs());
                *slot = nr;
            }
            lmax
        },
    );
    // hubs: parallel fixed-chunk partials, sequential fixed-order combine
    if !plan.hubs.is_empty() {
        let partials = hub_partials(plan, gt, contrib, None);
        for (h, &v) in plan.hubs.iter().enumerate() {
            let nr = c0 + alpha * plan.hub_sum(&partials, h);
            linf = linf.max((nr - r[v as usize]).abs());
            r_new[v as usize] = nr;
        }
    }
    linf
}

/// Static PageRank (Algorithm 1): cold start from 1/|V|, or warm start from
/// `r0` (the Naive-dynamic approach — identical loop, different init).
pub fn static_pagerank(
    g: &CsrGraph,
    gt: &CsrGraph,
    cfg: &PagerankConfig,
    r0: Option<&[f64]>,
) -> PagerankResult {
    let n = g.num_vertices();
    let start = Instant::now();
    let _mode = par::push_mode(par::mode_for(cfg.pool_persistent));
    let threads = par::resolve(cfg.threads);
    let be = simd::resolve(cfg.simd);
    let plan = StepPlan::build(gt, threads, be);

    let mut r: Vec<f64> = match r0 {
        Some(prev) => prev.to_vec(),
        None => vec![1.0 / n as f64; n],
    };
    let mut r_new = vec![0.0f64; n];
    let mut contrib = vec![0.0f64; n];
    let c0 = (1.0 - cfg.alpha) / n as f64;

    let mut iterations = 0;
    for _ in 0..cfg.max_iterations {
        let dangling = compute_contrib(threads, be, g, &r, &mut contrib);
        let c0_iter = c0 + cfg.alpha * (dangling / n as f64);
        let linf = step_plain(&plan, gt, &contrib, &r, &mut r_new, c0_iter, cfg.alpha);
        std::mem::swap(&mut r, &mut r_new);
        iterations += 1;
        if linf <= cfg.tau {
            break;
        }
    }
    PagerankResult::new(r, iterations, start.elapsed())
}

/// Naive-dynamic: warm start from the previous snapshot's ranks.
pub fn naive_dynamic(
    g: &CsrGraph,
    gt: &CsrGraph,
    cfg: &PagerankConfig,
    prev: &[f64],
) -> PagerankResult {
    static_pagerank(g, gt, cfg, Some(prev))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generators::er;

    fn ranks_sum_to_one(r: &[f64]) -> bool {
        (r.iter().sum::<f64>() - 1.0).abs() < 1e-6
    }

    #[test]
    fn static_converges_on_ring() {
        // symmetric ring: uniform ranks
        let n = 10;
        let mut adj: Vec<Vec<u32>> = (0..n)
            .map(|v| vec![v as u32, ((v + 1) % n) as u32])
            .collect();
        adj[0].sort_unstable();
        let g = CsrGraph::from_adjacency(&adj);
        let gt = g.transpose();
        let res = static_pagerank(&g, &gt, &PagerankConfig::default(), None);
        assert!(res.iterations < 100);
        for &x in &res.ranks {
            assert!((x - 0.1).abs() < 1e-9, "{x}");
        }
    }

    #[test]
    fn static_sums_to_one_random() {
        let g = er::generate(500, 5.0, 3).to_csr();
        let gt = g.transpose();
        let res = static_pagerank(&g, &gt, &PagerankConfig::default(), None);
        assert!(ranks_sum_to_one(&res.ranks));
        assert!(res.iterations > 5 && res.iterations < 200);
    }

    #[test]
    fn warm_start_converges_faster() {
        let g = er::generate(800, 5.0, 7).to_csr();
        let gt = g.transpose();
        let cfg = PagerankConfig::default();
        let cold = static_pagerank(&g, &gt, &cfg, None);
        let warm = static_pagerank(&g, &gt, &cfg, Some(&cold.ranks));
        assert!(warm.iterations <= 2, "warm restart on same graph: {}", warm.iterations);
    }

    #[test]
    fn higher_indegree_higher_rank() {
        // star: everyone points at 0
        let n = 20usize;
        let mut adj: Vec<Vec<u32>> = (0..n).map(|v| vec![v as u32]).collect();
        for v in 1..n {
            adj[v].push(0);
        }
        let g = CsrGraph::from_adjacency(&adj);
        let gt = g.transpose();
        let res = static_pagerank(&g, &gt, &PagerankConfig::default(), None);
        assert!(res.ranks[0] > res.ranks[1] * 5.0);
    }

    #[test]
    fn dead_end_teleport_fallback_is_finite_and_stochastic() {
        // vertex 1 has no out-edges; in release this used to yield NaN ranks
        let g = CsrGraph::from_edges(3, &[(0, 1), (2, 0), (2, 1)]);
        let gt = g.transpose();
        let res = static_pagerank(&g, &gt, &PagerankConfig::default(), None);
        assert!(res.ranks.iter().all(|r| r.is_finite() && *r > 0.0));
        assert!(ranks_sum_to_one(&res.ranks), "teleport fallback preserves mass");
    }

    #[test]
    fn scalar_and_vector_backends_bitwise_identical() {
        use crate::util::SimdPolicy;
        // mix of hub path (star center), low-degree path, and a dead end —
        // exercises gather_sum, hub chunks and the dangling sum on both
        // backends
        let n = 2600usize;
        let mut adj: Vec<Vec<u32>> = (0..n).map(|v| vec![v as u32]).collect();
        for v in 1..n {
            adj[v].push(0);
        }
        adj[5].clear(); // dead end
        let g = CsrGraph::from_adjacency(&adj);
        let gt = g.transpose();
        let scalar = static_pagerank(
            &g,
            &gt,
            &PagerankConfig::default().with_simd(SimdPolicy::Scalar),
            None,
        );
        for threads in [1, 4] {
            let cfg = PagerankConfig::default()
                .with_simd(SimdPolicy::Vector)
                .with_threads(threads);
            let vector = static_pagerank(&g, &gt, &cfg, None);
            assert_eq!(vector.iterations, scalar.iterations, "t={threads}");
            for (a, b) in vector.ranks.iter().zip(&scalar.ranks) {
                assert_eq!(a.to_bits(), b.to_bits(), "t={threads}");
            }
        }
    }

    #[test]
    fn hub_path_bitwise_stable_across_thread_counts() {
        // star center has in-degree n-1 > HUB_IN_DEGREE: exercises the
        // fixed-chunk hub pass at every thread count
        let n = 3000usize;
        let mut adj: Vec<Vec<u32>> = (0..n).map(|v| vec![v as u32]).collect();
        for v in 1..n {
            adj[v].push(0);
        }
        let g = CsrGraph::from_adjacency(&adj);
        let gt = g.transpose();
        assert!(gt.degree(0) > HUB_IN_DEGREE);
        let base = static_pagerank(&g, &gt, &PagerankConfig::default().with_threads(1), None);
        for threads in [2, 4, 8] {
            let cfg = PagerankConfig::default().with_threads(threads);
            let res = static_pagerank(&g, &gt, &cfg, None);
            assert_eq!(res.iterations, base.iterations, "t={threads}");
            for (a, b) in res.ranks.iter().zip(&base.ranks) {
                assert_eq!(a.to_bits(), b.to_bits(), "t={threads}");
            }
        }
    }
}
