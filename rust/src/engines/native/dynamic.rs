//! Native DT / DF / DF-P PageRank (paper Algorithms 2-3, CPU substrate).

use std::time::Instant;

use super::affected::{dt_affected, expand_affected, initial_affected};
use super::pull_contrib;
use crate::batch::BatchUpdate;
use crate::engines::config::PagerankConfig;
use crate::engines::PagerankResult;
use crate::graph::CsrGraph;

/// Dynamic Traversal: mark everything reachable from the update (BFS over
/// old + new graph), then run masked Eq. 1 iterations over that fixed set.
pub fn dynamic_traversal(
    g: &CsrGraph,
    gt: &CsrGraph,
    g_old: &CsrGraph,
    cfg: &PagerankConfig,
    prev: &[f64],
    batch: &BatchUpdate,
) -> PagerankResult {
    let n = g.num_vertices();
    let start = Instant::now();
    let aff = dt_affected(g, g_old, batch);
    let initially_affected = aff.iter().filter(|&&x| x != 0).count();

    let mut r = prev.to_vec();
    let mut r_new = prev.to_vec();
    let mut contrib = vec![0.0f64; n];
    let c0 = (1.0 - cfg.alpha) / n as f64;

    let mut iterations = 0;
    for _ in 0..cfg.max_iterations {
        for (u, c) in contrib.iter_mut().enumerate() {
            *c = r[u] / g.degree(u as u32) as f64;
        }
        let mut linf = 0.0f64;
        for (v, out) in r_new.iter_mut().enumerate() {
            if aff[v] == 0 {
                *out = r[v];
                continue;
            }
            let c = pull_contrib(gt, &contrib, v as u32);
            let nr = c0 + cfg.alpha * c;
            linf = linf.max((nr - r[v]).abs());
            *out = nr;
        }
        std::mem::swap(&mut r, &mut r_new);
        iterations += 1;
        if linf <= cfg.tau {
            break;
        }
    }
    PagerankResult { ranks: r, iterations, elapsed: start.elapsed(), initially_affected }
}

/// Dynamic Frontier (`prune = false`) and DF with Pruning (`prune = true`):
/// Algorithm 2 with the Algorithm 3 update rule — Eq. 1 for DF, the
/// closed-loop Eq. 2 for DF-P; frontier expansion deferred to a separate
/// pass after each iteration, exactly as the GPU implementation does.
pub fn dynamic_frontier(
    g: &CsrGraph,
    gt: &CsrGraph,
    cfg: &PagerankConfig,
    prev: &[f64],
    batch: &BatchUpdate,
    prune: bool,
) -> PagerankResult {
    let n = g.num_vertices();
    let start = Instant::now();

    let (mut dv, mut dn) = initial_affected(n, batch);
    expand_affected(&mut dv, &dn, g);
    let initially_affected = dv.iter().filter(|&&x| x != 0).count();

    let mut r = prev.to_vec();
    let mut r_new = prev.to_vec();
    let mut contrib = vec![0.0f64; n];
    let c0 = (1.0 - cfg.alpha) / n as f64;

    let mut iterations = 0;
    for _ in 0..cfg.max_iterations {
        for (u, c) in contrib.iter_mut().enumerate() {
            *c = r[u] / g.degree(u as u32) as f64;
        }
        dn.iter_mut().for_each(|x| *x = 0);

        let mut linf = 0.0f64;
        for v in 0..n {
            if dv[v] == 0 {
                r_new[v] = r[v];
                continue;
            }
            let c = pull_contrib(gt, &contrib, v as u32);
            let d_v = g.degree(v as u32) as f64;
            let nr = if prune {
                // Eq. 2: K excludes the self-loop term of the old rank.
                let k = c - r[v] / d_v;
                (cfg.alpha * k + c0) / (1.0 - cfg.alpha / d_v)
            } else {
                c0 + cfg.alpha * c
            };
            let delta = (nr - r[v]).abs();
            let denom = nr.max(r[v]);
            let rel = if denom > 0.0 { delta / denom } else { 0.0 };
            if prune && rel <= cfg.tau_prune {
                dv[v] = 0; // contract the affected set
            }
            if rel > cfg.tau_frontier {
                dn[v] = 1; // expand later via expandAffected
            }
            r_new[v] = nr;
            linf = linf.max(delta);
        }

        std::mem::swap(&mut r, &mut r_new);
        iterations += 1;
        if linf <= cfg.tau {
            break;
        }
        expand_affected(&mut dv, &dn, g);
    }
    PagerankResult { ranks: r, iterations, elapsed: start.elapsed(), initially_affected }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batch;
    use crate::engines::native::static_pagerank;
    use crate::generators::er;

    fn l1(a: &[f64], b: &[f64]) -> f64 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum()
    }

    fn setup(n: usize, seed: u64) -> (crate::graph::GraphBuilder, Vec<f64>, PagerankConfig) {
        let b = er::generate(n, 5.0, seed);
        let g = b.to_csr();
        let gt = g.transpose();
        let cfg = PagerankConfig::default();
        let prev = static_pagerank(&g, &gt, &cfg, None).ranks;
        (b, prev, cfg)
    }

    #[test]
    fn df_and_dfp_track_static_after_update() {
        for seed in [1u64, 2, 3] {
            let (mut b, prev, cfg) = setup(400, seed);
            let old_g = b.to_csr();
            let upd = batch::random_batch(&b, 10, 0.8, seed + 50);
            batch::apply(&mut b, &upd);
            let g = b.to_csr();
            let gt = g.transpose();
            let want = static_pagerank(&g, &gt, &cfg, None).ranks;

            for prune in [false, true] {
                let res = dynamic_frontier(&g, &gt, &cfg, &prev, &upd, prune);
                let err = l1(&res.ranks, &want);
                assert!(err < 1e-3, "prune={prune} seed={seed} err={err}");
                assert!(res.initially_affected > 0);
            }
            let res = dynamic_traversal(&g, &gt, &old_g, &cfg, &prev, &upd);
            assert!(l1(&res.ranks, &want) < 1e-6, "DT tracks static closely");
        }
    }

    #[test]
    fn dt_affected_superset_of_df_initial() {
        let (mut b, _prev, _cfg) = setup(300, 9);
        let old_g = b.to_csr();
        let upd = batch::random_batch(&b, 5, 0.8, 99);
        batch::apply(&mut b, &upd);
        let g = b.to_csr();
        let dt = dt_affected(&g, &old_g, &upd);
        let (mut dv, dn) = initial_affected(g.num_vertices(), &upd);
        expand_affected(&mut dv, &dn, &g);
        // DF's initial affected (minus deletion targets, which DT only
        // reaches if connected) is reachable from update sources -> subset.
        for v in 0..g.num_vertices() {
            if dv[v] != 0 && upd.deletions.iter().all(|&(_, t)| t as usize != v) {
                assert_eq!(dt[v], 1, "vertex {v} in DF init but not DT");
            }
        }
    }

    #[test]
    fn df_fewer_iterations_than_cold_static() {
        let (mut b, prev, cfg) = setup(600, 4);
        let upd = batch::random_batch(&b, 3, 1.0, 123);
        batch::apply(&mut b, &upd);
        let g = b.to_csr();
        let gt = g.transpose();
        let cold = static_pagerank(&g, &gt, &cfg, None);
        let df = dynamic_frontier(&g, &gt, &cfg, &prev, &upd, false);
        assert!(df.iterations <= cold.iterations);
    }

    #[test]
    fn empty_batch_converges_immediately() {
        let (b, prev, cfg) = setup(200, 11);
        let g = b.to_csr();
        let gt = g.transpose();
        let upd = BatchUpdate::default();
        let res = dynamic_frontier(&g, &gt, &cfg, &prev, &upd, true);
        assert_eq!(res.initially_affected, 0);
        assert!(res.iterations <= 1);
        assert_eq!(l1(&res.ranks, &prev), 0.0);
    }
}
