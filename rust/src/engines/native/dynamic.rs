//! Native DT / DF / DF-P PageRank (paper Algorithms 2-3, CPU substrate).
//!
//! Both approaches run their vertex passes on the persistent work-stealing
//! pool with the same degree split as the static engine (low in-degree
//! vertices blocked across lanes, hubs via fixed-chunk partial sums written
//! into chunk-indexed slots), and DF/DF-P expand the frontier with the
//! stealing push of [`expand_affected_threads`]. Decompositions are
//! thread-count and schedule invariant, so ranks and iteration counts are
//! bit-identical at every `threads` setting and under every steal order.

use std::time::Instant;

use super::affected::{dt_affected, expand_affected_threads, initial_affected};
use super::{compute_contrib, hub_partials, pull_contrib, StepPlan, HUB_IN_DEGREE};
use crate::batch::BatchUpdate;
use crate::engines::config::PagerankConfig;
use crate::engines::PagerankResult;
use crate::graph::CsrGraph;
use crate::util::par;
use crate::util::simd;

/// Dynamic Traversal: mark everything reachable from the update (BFS over
/// old + new graph), then run masked Eq. 1 iterations over that fixed set.
pub fn dynamic_traversal(
    g: &CsrGraph,
    gt: &CsrGraph,
    g_old: &CsrGraph,
    cfg: &PagerankConfig,
    prev: &[f64],
    batch: &BatchUpdate,
) -> PagerankResult {
    let n = g.num_vertices();
    let start = Instant::now();
    let _mode = par::push_mode(par::mode_for(cfg.pool_persistent));
    let threads = par::resolve(cfg.threads);
    let be = simd::resolve(cfg.simd);
    let plan = StepPlan::build(gt, threads, be);
    let aff = dt_affected(g, g_old, batch);
    let initially_affected = aff.iter().filter(|&&x| x != 0).count();

    let mut r = prev.to_vec();
    let mut r_new = prev.to_vec();
    let mut contrib = vec![0.0f64; n];
    let c0 = (1.0 - cfg.alpha) / n as f64;

    let mut iterations = 0;
    for _ in 0..cfg.max_iterations {
        let dangling = compute_contrib(threads, be, g, &r, &mut contrib);
        let c0_iter = c0 + cfg.alpha * (dangling / n as f64);

        let aff_ref = &aff;
        let r_ref = &r;
        let contrib_ref = &contrib;
        let mut linf = par::par_reduce(
            threads,
            par::DEFAULT_BLOCK,
            &mut r_new,
            0.0,
            f64::max,
            |start, out| {
                let mut lmax = 0.0f64;
                for (i, slot) in out.iter_mut().enumerate() {
                    let v = start + i;
                    if gt.degree(v as u32) > HUB_IN_DEGREE {
                        continue; // hub pass below owns this slot
                    }
                    if aff_ref[v] == 0 {
                        *slot = r_ref[v];
                        continue;
                    }
                    let c = pull_contrib(be, gt, contrib_ref, v as u32);
                    let nr = c0_iter + cfg.alpha * c;
                    lmax = lmax.max((nr - r_ref[v]).abs());
                    *slot = nr;
                }
                lmax
            },
        );
        if !plan.hubs.is_empty() {
            let partials = hub_partials(&plan, gt, &contrib, Some(&aff));
            for (h, &v) in plan.hubs.iter().enumerate() {
                let vi = v as usize;
                if aff[vi] == 0 {
                    r_new[vi] = r[vi];
                    continue;
                }
                let nr = c0_iter + cfg.alpha * plan.hub_sum(&partials, h);
                linf = linf.max((nr - r[vi]).abs());
                r_new[vi] = nr;
            }
        }

        std::mem::swap(&mut r, &mut r_new);
        iterations += 1;
        if linf <= cfg.tau {
            break;
        }
    }
    PagerankResult { ranks: r, iterations, elapsed: start.elapsed(), initially_affected }
}

/// The DF/DF-P update for one affected vertex: new rank plus the frontier
/// (δ_N) and prune (δ_V) decisions. `d_v = 0` (dead end) falls back to the
/// Eq. 1 form — Eq. 2's closed loop is undefined without the self-loop.
#[inline]
#[allow(clippy::too_many_arguments)]
fn df_update(
    c: f64,
    d_v: f64,
    old: f64,
    c0: f64,
    alpha: f64,
    prune: bool,
    cfg: &PagerankConfig,
    dv: &mut u8,
    dn: &mut u8,
) -> (f64, f64) {
    let nr = if prune && d_v > 0.0 {
        // Eq. 2: K excludes the self-loop term of the old rank.
        let k = c - old / d_v;
        (alpha * k + c0) / (1.0 - alpha / d_v)
    } else {
        c0 + alpha * c
    };
    let delta = (nr - old).abs();
    let denom = nr.max(old);
    let rel = if denom > 0.0 { delta / denom } else { 0.0 };
    if prune && rel <= cfg.tau_prune {
        *dv = 0; // contract the affected set
    }
    *dn = (rel > cfg.tau_frontier) as u8; // expand later via expandAffected
    (nr, delta)
}

/// Dynamic Frontier (`prune = false`) and DF with Pruning (`prune = true`):
/// Algorithm 2 with the Algorithm 3 update rule — Eq. 1 for DF, the
/// closed-loop Eq. 2 for DF-P; frontier expansion deferred to a separate
/// pass after each iteration, exactly as the GPU implementation does.
pub fn dynamic_frontier(
    g: &CsrGraph,
    gt: &CsrGraph,
    cfg: &PagerankConfig,
    prev: &[f64],
    batch: &BatchUpdate,
    prune: bool,
) -> PagerankResult {
    let n = g.num_vertices();
    let start = Instant::now();
    let _mode = par::push_mode(par::mode_for(cfg.pool_persistent));
    let threads = par::resolve(cfg.threads);
    let be = simd::resolve(cfg.simd);
    let plan = StepPlan::build(gt, threads, be);

    let (mut dv, mut dn) = initial_affected(n, batch);
    expand_affected_threads(&mut dv, &dn, g, threads);
    let initially_affected = dv.iter().filter(|&&x| x != 0).count();

    let mut r = prev.to_vec();
    let mut r_new = prev.to_vec();
    let mut contrib = vec![0.0f64; n];
    let c0 = (1.0 - cfg.alpha) / n as f64;

    let mut iterations = 0;
    for _ in 0..cfg.max_iterations {
        let dangling = compute_contrib(threads, be, g, &r, &mut contrib);
        let c0_iter = c0 + cfg.alpha * (dangling / n as f64);

        // one lockstep pass over (r_new, δ_V, δ_N): low in-degree vertices
        // updated in place, hub slots only have δ_N cleared (the hub pass
        // after the barrier owns the rest)
        let r_ref = &r;
        let contrib_ref = &contrib;
        let mut linf = par::par_for3_reduce(
            threads,
            par::DEFAULT_BLOCK,
            &mut r_new,
            &mut dv,
            &mut dn,
            0.0,
            f64::max,
            |start, out, bdv, bdn| {
                let mut lmax = 0.0f64;
                for i in 0..out.len() {
                    let v = start + i;
                    if gt.degree(v as u32) > HUB_IN_DEGREE {
                        bdn[i] = 0;
                        continue;
                    }
                    if bdv[i] == 0 {
                        out[i] = r_ref[v];
                        bdn[i] = 0;
                        continue;
                    }
                    let c = pull_contrib(be, gt, contrib_ref, v as u32);
                    let d_v = g.degree(v as u32) as f64;
                    let (nr, delta) = df_update(
                        c, d_v, r_ref[v], c0_iter, cfg.alpha, prune, cfg,
                        &mut bdv[i], &mut bdn[i],
                    );
                    out[i] = nr;
                    lmax = lmax.max(delta);
                }
                lmax
            },
        );
        // hubs: fixed-chunk partials in parallel, flag logic sequentially.
        // The pass above never touches a hub's δ_V flag, so the mask read
        // here is the pre-pass value, same as the sequential order.
        if !plan.hubs.is_empty() {
            let partials = hub_partials(&plan, gt, &contrib, Some(&dv));
            for (h, &v) in plan.hubs.iter().enumerate() {
                let vi = v as usize;
                if dv[vi] == 0 {
                    r_new[vi] = r[vi];
                    continue;
                }
                let c = plan.hub_sum(&partials, h);
                let d_v = g.degree(v) as f64;
                let (nr, delta) = df_update(
                    c, d_v, r[vi], c0_iter, cfg.alpha, prune, cfg,
                    &mut dv[vi], &mut dn[vi],
                );
                r_new[vi] = nr;
                linf = linf.max(delta);
            }
        }

        std::mem::swap(&mut r, &mut r_new);
        iterations += 1;
        if linf <= cfg.tau {
            break;
        }
        expand_affected_threads(&mut dv, &dn, g, threads);
    }
    PagerankResult { ranks: r, iterations, elapsed: start.elapsed(), initially_affected }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batch;
    use crate::engines::native::static_pagerank;
    use crate::generators::er;

    fn l1(a: &[f64], b: &[f64]) -> f64 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum()
    }

    fn setup(n: usize, seed: u64) -> (crate::graph::GraphBuilder, Vec<f64>, PagerankConfig) {
        let b = er::generate(n, 5.0, seed);
        let g = b.to_csr();
        let gt = g.transpose();
        let cfg = PagerankConfig::default();
        let prev = static_pagerank(&g, &gt, &cfg, None).ranks;
        (b, prev, cfg)
    }

    #[test]
    fn df_and_dfp_track_static_after_update() {
        for seed in [1u64, 2, 3] {
            let (mut b, prev, cfg) = setup(400, seed);
            let old_g = b.to_csr();
            let upd = batch::random_batch(&b, 10, 0.8, seed + 50);
            batch::apply(&mut b, &upd);
            let g = b.to_csr();
            let gt = g.transpose();
            let want = static_pagerank(&g, &gt, &cfg, None).ranks;

            for prune in [false, true] {
                let res = dynamic_frontier(&g, &gt, &cfg, &prev, &upd, prune);
                let err = l1(&res.ranks, &want);
                assert!(err < 1e-3, "prune={prune} seed={seed} err={err}");
                assert!(res.initially_affected > 0);
            }
            let res = dynamic_traversal(&g, &gt, &old_g, &cfg, &prev, &upd);
            assert!(l1(&res.ranks, &want) < 1e-6, "DT tracks static closely");
        }
    }

    #[test]
    fn dt_affected_superset_of_df_initial() {
        let (mut b, _prev, _cfg) = setup(300, 9);
        let old_g = b.to_csr();
        let upd = batch::random_batch(&b, 5, 0.8, 99);
        batch::apply(&mut b, &upd);
        let g = b.to_csr();
        let dt = dt_affected(&g, &old_g, &upd);
        let (mut dv, dn) = initial_affected(g.num_vertices(), &upd);
        expand_affected_threads(&mut dv, &dn, &g, 1);
        // DF's initial affected (minus deletion targets, which DT only
        // reaches if connected) is reachable from update sources -> subset.
        for v in 0..g.num_vertices() {
            if dv[v] != 0 && upd.deletions.iter().all(|&(_, t)| t as usize != v) {
                assert_eq!(dt[v], 1, "vertex {v} in DF init but not DT");
            }
        }
    }

    #[test]
    fn df_fewer_iterations_than_cold_static() {
        let (mut b, prev, cfg) = setup(600, 4);
        let upd = batch::random_batch(&b, 3, 1.0, 123);
        batch::apply(&mut b, &upd);
        let g = b.to_csr();
        let gt = g.transpose();
        let cold = static_pagerank(&g, &gt, &cfg, None);
        let df = dynamic_frontier(&g, &gt, &cfg, &prev, &upd, false);
        assert!(df.iterations <= cold.iterations);
    }

    #[test]
    fn empty_batch_converges_immediately() {
        let (b, prev, cfg) = setup(200, 11);
        let g = b.to_csr();
        let gt = g.transpose();
        let upd = BatchUpdate::default();
        let res = dynamic_frontier(&g, &gt, &cfg, &prev, &upd, true);
        assert_eq!(res.initially_affected, 0);
        assert!(res.iterations <= 1);
        assert_eq!(l1(&res.ranks, &prev), 0.0);
    }
}
