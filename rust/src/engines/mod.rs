//! PageRank engines: the five approaches of the paper (Static,
//! Naive-dynamic, Dynamic Traversal, Dynamic Frontier, DF with Pruning) on
//! two substrates — [`native`] (multicore CPU, the paper's comparator [49])
//! and [`device`] (the AOT-compiled artifacts on the PJRT "GPU") — plus the
//! [`baselines`] modeling Hornet's and Gunrock's algorithmic choices.

pub mod baselines;
pub mod config;
pub mod device;
pub mod error;
pub mod native;

use std::time::Duration;

/// The five ways to obtain ranks after a batch update (paper Table 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Approach {
    /// Recompute from scratch (cold start).
    Static,
    /// Warm-start from the previous snapshot's ranks, process all vertices.
    NaiveDynamic,
    /// Warm-start + process only vertices reachable from the update (BFS).
    DynamicTraversal,
    /// Warm-start + incrementally expanding affected frontier.
    DynamicFrontier,
    /// Dynamic Frontier with Pruning (contracts the affected set too).
    DynamicFrontierPruning,
}

impl Approach {
    pub const ALL: [Approach; 5] = [
        Approach::Static,
        Approach::NaiveDynamic,
        Approach::DynamicTraversal,
        Approach::DynamicFrontier,
        Approach::DynamicFrontierPruning,
    ];

    /// Parse a CLI name (static / nd / dt / df / dfp).
    pub fn parse(s: &str) -> Option<Approach> {
        match s.to_ascii_lowercase().as_str() {
            "static" => Some(Approach::Static),
            "nd" | "naive-dynamic" => Some(Approach::NaiveDynamic),
            "dt" | "dynamic-traversal" => Some(Approach::DynamicTraversal),
            "df" | "dynamic-frontier" => Some(Approach::DynamicFrontier),
            "dfp" | "df-p" | "dynamic-frontier-pruning" => {
                Some(Approach::DynamicFrontierPruning)
            }
            _ => None,
        }
    }

    /// Short label used in reports (matches the paper's figures).
    pub fn label(&self) -> &'static str {
        match self {
            Approach::Static => "Static",
            Approach::NaiveDynamic => "ND",
            Approach::DynamicTraversal => "DT",
            Approach::DynamicFrontier => "DF",
            Approach::DynamicFrontierPruning => "DF-P",
        }
    }

    /// How much of the previous snapshot's work the approach reuses, as a
    /// rank on the degradation ladder: Static (0, reuses nothing) < ND (1)
    /// < DT (2) < DF (3) < DF-P (4, reuses the most). The policy tests
    /// assert selection degrades monotonically along this scale as batches
    /// grow.
    pub fn incrementality(&self) -> u8 {
        match self {
            Approach::Static => 0,
            Approach::NaiveDynamic => 1,
            Approach::DynamicTraversal => 2,
            Approach::DynamicFrontier => 3,
            Approach::DynamicFrontierPruning => 4,
        }
    }
}

/// Outcome of one PageRank computation.
#[derive(Debug, Clone)]
pub struct PagerankResult {
    /// Converged ranks, one per vertex.
    pub ranks: Vec<f64>,
    /// Power iterations executed.
    pub iterations: usize,
    /// Measured runtime per the paper's Section 5.1.5: includes
    /// partitioning, initial affected marking and convergence detection;
    /// excludes host<->device transfers and allocation.
    pub elapsed: Duration,
    /// Vertices initially marked affected (0 for Static/ND).
    pub initially_affected: usize,
}

impl PagerankResult {
    pub fn new(ranks: Vec<f64>, iterations: usize, elapsed: Duration) -> Self {
        Self { ranks, iterations, elapsed, initially_affected: 0 }
    }
}
