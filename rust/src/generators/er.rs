//! Erdős–Rényi G(n, m) generator — the neutral workload used by unit and
//! property tests (no structural signature to bias an approach).

use crate::graph::{GraphBuilder, VertexId};
use crate::util::Rng;

/// ~`avg_deg * n` random directed edges (duplicates dropped) + self-loops.
pub fn generate(n: usize, avg_deg: f64, seed: u64) -> GraphBuilder {
    let mut rng = Rng::seed_from_u64(seed);
    let mut b = GraphBuilder::new(n);
    let m = (avg_deg * n as f64) as usize;
    for _ in 0..m {
        let u = rng.gen_range(n) as VertexId;
        let v = rng.gen_range(n) as VertexId;
        b.insert_edge(u, v);
    }
    b.ensure_self_loops();
    b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape() {
        let g = generate(500, 4.0, 11).to_csr();
        assert_eq!(g.num_vertices(), 500);
        assert!(g.has_no_dead_ends());
        assert!(g.num_edges() >= 500); // at least the self-loops
    }
}
