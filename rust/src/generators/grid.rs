//! Road-network-like generator (asia_osm / europe_osm stand-ins): a 2D grid
//! with bidirectional edges, a small fraction of random diagonal shortcuts,
//! and random holes — low average degree (~3), huge diameter. These are the
//! graphs where the paper's DT approach collapses (everything is reachable
//! but convergence is traversal-bound).

use crate::graph::{GraphBuilder, VertexId};
use crate::util::Rng;

/// `rows x cols` grid; `hole_frac` of vertices keep no lateral edges
/// (intersections removed), `shortcut_frac` adds highway-like skips.
pub fn generate(rows: usize, cols: usize, seed: u64) -> GraphBuilder {
    let n = rows * cols;
    let mut rng = Rng::seed_from_u64(seed);
    let mut b = GraphBuilder::new(n);
    let id = |r: usize, c: usize| (r * cols + c) as VertexId;
    for r in 0..rows {
        for c in 0..cols {
            if c + 1 < cols && rng.gen_f64() > 0.05 {
                b.insert_edge(id(r, c), id(r, c + 1));
                b.insert_edge(id(r, c + 1), id(r, c));
            }
            if r + 1 < rows && rng.gen_f64() > 0.05 {
                b.insert_edge(id(r, c), id(r + 1, c));
                b.insert_edge(id(r + 1, c), id(r, c));
            }
        }
    }
    // sparse highway shortcuts (~0.5% of vertices)
    for _ in 0..(n / 200).max(1) {
        let u = rng.gen_range(n) as VertexId;
        let v = rng.gen_range(n) as VertexId;
        b.insert_edge(u, v);
        b.insert_edge(v, u);
    }
    b.ensure_self_loops();
    b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn low_degree_large_graph() {
        let g = generate(32, 32, 3).to_csr();
        assert_eq!(g.num_vertices(), 1024);
        let avg = g.num_edges() as f64 / 1024.0;
        assert!(avg > 2.0 && avg < 6.0, "avg degree {avg}");
        assert!(g.has_no_dead_ends());
    }

    #[test]
    fn mostly_symmetric() {
        let g = generate(16, 16, 5).to_csr();
        let mut sym = 0;
        let mut tot = 0;
        for (u, v) in g.edges() {
            if u != v {
                tot += 1;
                if g.neighbors(v).contains(&u) {
                    sym += 1;
                }
            }
        }
        assert!(sym as f64 / tot as f64 > 0.99);
    }
}
