//! Named dataset stand-ins for the paper's Table 4 (12 large static graphs).
//!
//! Each entry mirrors one SuiteSparse graph: same family (web / social /
//! road / k-mer), same relative size ordering and average-degree class,
//! scaled to fit the largest device tier (t16: V < 65536, E <= 2^20 with
//! head-room for insertion batches). The structural signature — power-law
//! hubs for web/social, low-degree large-diameter lattices/chains for
//! road/k-mer — is what drives every per-family effect in the paper's
//! evaluation, and is preserved.

use crate::graph::GraphBuilder;

use super::{chain, grid, rmat};

/// Dataset family, following Table 4's grouping.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Family {
    Web,
    Social,
    Road,
    Kmer,
}

/// A named synthetic stand-in for one of the paper's graphs.
#[derive(Clone, Copy)]
pub struct Dataset {
    /// Paper's dataset name this stands in for.
    pub name: &'static str,
    pub family: Family,
    /// Generator seed (fixed: datasets are reproducible artifacts).
    pub seed: u64,
    build: fn(u64) -> GraphBuilder,
}

impl Dataset {
    pub fn build(&self) -> GraphBuilder {
        (self.build)(self.seed)
    }
}

impl std::fmt::Debug for Dataset {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Dataset")
            .field("name", &self.name)
            .field("family", &self.family)
            .finish()
    }
}

macro_rules! web {
    ($scale:expr, $deg:expr) => {
        |seed| rmat::generate($scale, $deg, rmat::RmatParams::WEB, seed)
    };
}
macro_rules! social {
    ($scale:expr, $deg:expr) => {
        |seed| rmat::generate($scale, $deg, rmat::RmatParams::SOCIAL, seed)
    };
}

/// Table 4 stand-ins, in the paper's order.
pub const DATASETS: &[Dataset] = &[
    Dataset { name: "indochina-2004", family: Family::Web, seed: 101, build: web!(14, 12.0) },
    Dataset { name: "arabic-2005", family: Family::Web, seed: 102, build: web!(15, 13.0) },
    Dataset { name: "uk-2005", family: Family::Web, seed: 103, build: web!(15, 11.0) },
    Dataset { name: "webbase-2001", family: Family::Web, seed: 104, build: web!(15, 5.0) },
    Dataset { name: "it-2004", family: Family::Web, seed: 105, build: web!(14, 14.0) },
    Dataset { name: "sk-2005", family: Family::Web, seed: 106, build: web!(15, 16.0) },
    Dataset { name: "com-LiveJournal", family: Family::Social, seed: 107, build: social!(14, 9.0) },
    Dataset { name: "com-Orkut", family: Family::Social, seed: 108, build: social!(13, 38.0) },
    Dataset { name: "asia_osm", family: Family::Road, seed: 109, build: |s| grid::generate(128, 96, s) },
    Dataset { name: "europe_osm", family: Family::Road, seed: 110, build: |s| grid::generate(224, 224, s) },
    Dataset { name: "kmer_A2a", family: Family::Kmer, seed: 111, build: |s| chain::generate(40_000, 120, s) },
    Dataset { name: "kmer_V1r", family: Family::Kmer, seed: 112, build: |s| chain::generate(52_000, 150, s) },
];

/// Look up a stand-in by (paper) name.
pub fn dataset(name: &str) -> Option<&'static Dataset> {
    DATASETS.iter().find(|d| d.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_datasets_build_and_fit_t16() {
        for d in DATASETS {
            let g = d.build().to_csr();
            assert!(g.num_vertices() < 65_535, "{} too many vertices", d.name);
            assert!(g.num_edges() < 900_000, "{} too many edges", d.name);
            assert!(g.has_no_dead_ends(), "{}", d.name);
        }
    }

    #[test]
    fn lookup() {
        assert!(dataset("sk-2005").is_some());
        assert!(dataset("nope").is_none());
        assert_eq!(dataset("asia_osm").unwrap().family, Family::Road);
    }

    #[test]
    fn family_signatures() {
        // web: hubby; road: flat
        let web = dataset("it-2004").unwrap().build().to_csr().transpose();
        let road = dataset("asia_osm").unwrap().build().to_csr().transpose();
        let max_web = web.degrees().into_iter().max().unwrap();
        let max_road = road.degrees().into_iter().max().unwrap();
        assert!(max_web > 100, "web hub {max_web}");
        assert!(max_road < 12, "road max degree {max_road}");
    }
}
