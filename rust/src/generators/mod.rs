//! Synthetic graph generators — stand-ins for the paper's datasets.
//!
//! We do not ship the multi-gigabyte SuiteSparse / SNAP graphs of Tables 3-4;
//! per DESIGN.md §3 each dataset *family* is reproduced by a generator with
//! the same structural signature (degree distribution, diameter class),
//! which is what drives the paper's per-family effects (e.g. DT collapsing
//! on road/k-mer graphs, DF-P winning on low-degree graphs).

pub mod chain;
pub mod er;
pub mod families;
pub mod grid;
pub mod rmat;

pub use families::{dataset, Dataset, DATASETS};
