//! Protein k-mer-like generator (kmer_A2a / kmer_V1r stand-ins): long
//! near-linear chains with occasional branches — degree ~3, enormous
//! diameter, many weakly-connected components. Structurally these behave
//! like the GenBank k-mer graphs in the paper's Table 4.

use crate::graph::{GraphBuilder, VertexId};
use crate::util::Rng;

/// `n` vertices arranged in `n / chain_len` chains with ~5% branch points.
pub fn generate(n: usize, chain_len: usize, seed: u64) -> GraphBuilder {
    let mut rng = Rng::seed_from_u64(seed);
    let mut b = GraphBuilder::new(n);
    let mut v = 0usize;
    while v + 1 < n {
        let end = (v + chain_len).min(n - 1);
        for u in v..end {
            b.insert_edge(u as VertexId, (u + 1) as VertexId);
            b.insert_edge((u + 1) as VertexId, u as VertexId);
            // branch: fork to a random earlier vertex of this chain
            if u > v + 2 && rng.gen_f64() < 0.05 {
                let t = (v + rng.gen_range(u - v)) as VertexId;
                b.insert_edge(u as VertexId, t);
                b.insert_edge(t, u as VertexId);
            }
        }
        v = end + 1;
    }
    b.ensure_self_loops();
    b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_shape() {
        let g = generate(2000, 100, 7).to_csr();
        assert_eq!(g.num_vertices(), 2000);
        let avg = g.num_edges() as f64 / 2000.0;
        assert!(avg > 2.5 && avg < 4.0, "avg degree {avg}");
        assert!(g.has_no_dead_ends());
    }

    #[test]
    fn max_degree_small() {
        let g = generate(1000, 50, 1).to_csr();
        let gt = g.transpose();
        let max_in = gt.degrees().into_iter().max().unwrap();
        assert!(max_in < 12, "k-mer graphs have no hubs, got {max_in}");
    }
}
