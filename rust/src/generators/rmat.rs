//! R-MAT recursive-matrix generator (Chakrabarti et al.): power-law in/out
//! degrees with community structure. Parameterized to mimic web crawls
//! (skewed, a≈0.57) and social networks (denser, more symmetric).

use crate::graph::{GraphBuilder, VertexId};
use crate::util::Rng;

/// R-MAT quadrant probabilities (a + b + c + d = 1).
#[derive(Debug, Clone, Copy)]
pub struct RmatParams {
    pub a: f64,
    pub b: f64,
    pub c: f64,
}

impl RmatParams {
    /// Web-graph-like skew (indochina/arabic/uk/webbase/it/sk stand-ins).
    pub const WEB: RmatParams = RmatParams { a: 0.57, b: 0.19, c: 0.19 };
    /// Social-network-like (LiveJournal/Orkut stand-ins).
    pub const SOCIAL: RmatParams = RmatParams { a: 0.45, b: 0.22, c: 0.22 };
}

/// Generate an R-MAT digraph with `n = 2^scale` vertices and ~`avg_deg * n`
/// edges (duplicates dropped), self-loops added.
pub fn generate(scale: u32, avg_deg: f64, params: RmatParams, seed: u64) -> GraphBuilder {
    let n: usize = 1 << scale;
    let m = (avg_deg * n as f64) as usize;
    let mut rng = Rng::seed_from_u64(seed);
    let mut b = GraphBuilder::new(n);
    let RmatParams { a, b: pb, c } = params;
    for _ in 0..m {
        let (mut lo_u, mut lo_v) = (0usize, 0usize);
        let mut half = n >> 1;
        while half > 0 {
            let r: f64 = rng.gen_f64();
            // add per-level noise so degree sequence is not too regular
            let (u_hi, v_hi) = if r < a {
                (false, false)
            } else if r < a + pb {
                (false, true)
            } else if r < a + pb + c {
                (true, false)
            } else {
                (true, true)
            };
            if u_hi {
                lo_u += half;
            }
            if v_hi {
                lo_v += half;
            }
            half >>= 1;
        }
        b.insert_edge(lo_u as VertexId, lo_v as VertexId);
    }
    b.ensure_self_loops();
    b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_requested_shape() {
        let g = generate(8, 4.0, RmatParams::WEB, 42).to_csr();
        assert_eq!(g.num_vertices(), 256);
        // ~4*256 edges + up to 256 self loops, minus duplicates
        assert!(g.num_edges() > 700 && g.num_edges() <= 256 * 4 + 256);
        assert!(g.has_no_dead_ends());
    }

    #[test]
    fn power_law_skew() {
        let g = generate(10, 8.0, RmatParams::WEB, 1).to_csr();
        let gt = g.transpose();
        let mut degs = gt.degrees();
        degs.sort_unstable_by(|a, b| b.cmp(a));
        // hub in-degree far above average
        assert!(degs[0] as f64 > 4.0 * (g.num_edges() as f64 / 1024.0));
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(7, 4.0, RmatParams::SOCIAL, 9).to_csr();
        let b = generate(7, 4.0, RmatParams::SOCIAL, 9).to_csr();
        assert_eq!(a, b);
        let c = generate(7, 4.0, RmatParams::SOCIAL, 10).to_csr();
        assert_ne!(a, c);
    }
}
