//! Batch validation: classify every edit of a [`BatchUpdate`] against the
//! live graph *before* anything is applied, so a malformed batch (out-of-range
//! vertex id, duplicate insertion, phantom deletion) can never corrupt the
//! CSR or panic the builder.
//!
//! The classification mirrors the apply order of [`crate::batch::apply`]
//! (all deletions first, then all insertions), so intra-batch interactions —
//! deleting the same edge twice, inserting an edge twice, or deleting and
//! re-inserting one edge in a single batch — are resolved exactly the way
//! the clean subset will later execute. The coordinator applies
//! [`ValidatedBatch::clean`] and reports the quarantined remainder instead
//! of failing the whole request.

use std::collections::HashSet;
use std::fmt;

use super::BatchUpdate;
use crate::graph::{GraphBuilder, VertexId};

/// Which half of the batch an edit came from.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EditKind {
    Insert,
    Delete,
}

impl EditKind {
    pub fn label(&self) -> &'static str {
        match self {
            EditKind::Insert => "insert",
            EditKind::Delete => "delete",
        }
    }
}

/// Why an edit was quarantined instead of applied.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum UpdateError {
    /// An endpoint is `>= num_vertices` (the builder would panic on it).
    OutOfRange { num_vertices: usize },
    /// `u == v`: self-loops model dead-end elimination and are managed by
    /// `ensure_self_loops`, never by client batches.
    SelfLoop,
    /// The edge already exists (in the graph, or inserted earlier in this
    /// same batch).
    DuplicateInsertion,
    /// The edge does not exist (never inserted, or already deleted earlier
    /// in this same batch).
    PhantomDeletion,
}

impl UpdateError {
    pub fn label(&self) -> &'static str {
        match self {
            UpdateError::OutOfRange { .. } => "out-of-range",
            UpdateError::SelfLoop => "self-loop",
            UpdateError::DuplicateInsertion => "duplicate-insertion",
            UpdateError::PhantomDeletion => "phantom-deletion",
        }
    }
}

impl fmt::Display for UpdateError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            UpdateError::OutOfRange { num_vertices } => {
                write!(f, "vertex id out of range (graph has {num_vertices} vertices)")
            }
            UpdateError::SelfLoop => write!(f, "self-loops are reserved for dead-end elimination"),
            UpdateError::DuplicateInsertion => write!(f, "edge already present"),
            UpdateError::PhantomDeletion => write!(f, "edge not present"),
        }
    }
}

impl std::error::Error for UpdateError {}

/// One quarantined edit with its diagnosis.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Rejection {
    pub kind: EditKind,
    pub edge: (VertexId, VertexId),
    pub error: UpdateError,
}

impl fmt::Display for Rejection {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{} ({}, {}): {}",
            self.kind.label(),
            self.edge.0,
            self.edge.1,
            self.error
        )
    }
}

/// The outcome of validating a batch: the applicable subset plus a
/// quarantine report for everything rejected.
#[derive(Debug, Clone, Default)]
pub struct ValidatedBatch {
    /// Edits safe to apply, in original order within each half.
    pub clean: BatchUpdate,
    /// Edits rejected, with the reason each one was quarantined.
    pub rejections: Vec<Rejection>,
}

impl ValidatedBatch {
    pub fn quarantined(&self) -> usize {
        self.rejections.len()
    }

    pub fn is_fully_clean(&self) -> bool {
        self.rejections.is_empty()
    }

    /// One-line quarantine report (`"quarantined 3/10: out-of-range=2
    /// phantom-deletion=1"`), empty string when nothing was rejected.
    pub fn summary(&self) -> String {
        if self.rejections.is_empty() {
            return String::new();
        }
        let mut counts: Vec<(&'static str, usize)> = Vec::new();
        for r in &self.rejections {
            let label = r.error.label();
            match counts.iter_mut().find(|(l, _)| *l == label) {
                Some((_, c)) => *c += 1,
                None => counts.push((label, 1)),
            }
        }
        let total = self.clean.len() + self.rejections.len();
        let detail: Vec<String> =
            counts.iter().map(|(l, c)| format!("{l}={c}")).collect();
        format!("quarantined {}/{}: {}", self.rejections.len(), total, detail.join(" "))
    }
}

/// Classify every edit of `batch` against the live graph `g`. Pure: neither
/// the graph nor the batch is modified. Applying [`ValidatedBatch::clean`]
/// via [`crate::batch::apply`] is guaranteed panic-free and changes exactly
/// `clean.len()` edges.
pub fn validate(g: &GraphBuilder, batch: &BatchUpdate) -> ValidatedBatch {
    let n = g.num_vertices();
    let mut out = ValidatedBatch::default();
    let in_range = |u: VertexId, v: VertexId| (u as usize) < n && (v as usize) < n;

    // Deletions run first (mirrors batch::apply). Track what this batch has
    // deleted so a second deletion of the same edge is a phantom.
    let mut deleted: HashSet<(VertexId, VertexId)> = HashSet::new();
    for &(u, v) in &batch.deletions {
        let reject = |error| Rejection { kind: EditKind::Delete, edge: (u, v), error };
        if !in_range(u, v) {
            out.rejections.push(reject(UpdateError::OutOfRange { num_vertices: n }));
        } else if u == v {
            out.rejections.push(reject(UpdateError::SelfLoop));
        } else if !g.has_edge(u, v) || deleted.contains(&(u, v)) {
            out.rejections.push(reject(UpdateError::PhantomDeletion));
        } else {
            deleted.insert((u, v));
            out.clean.deletions.push((u, v));
        }
    }

    // Insertions run second: an edge deleted above may be re-inserted; an
    // edge inserted earlier in this batch is a duplicate.
    let mut inserted: HashSet<(VertexId, VertexId)> = HashSet::new();
    for &(u, v) in &batch.insertions {
        let reject = |error| Rejection { kind: EditKind::Insert, edge: (u, v), error };
        if !in_range(u, v) {
            out.rejections.push(reject(UpdateError::OutOfRange { num_vertices: n }));
        } else if u == v {
            out.rejections.push(reject(UpdateError::SelfLoop));
        } else {
            let present =
                (g.has_edge(u, v) && !deleted.contains(&(u, v))) || inserted.contains(&(u, v));
            if present {
                out.rejections.push(reject(UpdateError::DuplicateInsertion));
            } else {
                inserted.insert((u, v));
                out.clean.insertions.push((u, v));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batch;
    use crate::generators::er;

    fn graph() -> GraphBuilder {
        let mut g = GraphBuilder::from_edges(5, [(0, 1), (1, 2), (2, 3)]);
        g.ensure_self_loops();
        g
    }

    #[test]
    fn clean_batch_passes_untouched() {
        let g = graph();
        let b = BatchUpdate {
            deletions: vec![(0, 1)],
            insertions: vec![(3, 4), (4, 0)],
        };
        let v = validate(&g, &b);
        assert!(v.is_fully_clean());
        assert_eq!(v.clean, b);
        assert_eq!(v.summary(), "");
    }

    #[test]
    fn classifies_every_error_kind() {
        let g = graph();
        let b = BatchUpdate {
            deletions: vec![
                (5, 0), // out of range (id == num_vertices)
                (1, 1), // self-loop (protected)
                (3, 4), // phantom: never existed
            ],
            insertions: vec![
                (0, 9), // out of range
                (2, 2), // self-loop
                (0, 1), // duplicate: already in graph
                (4, 0), // ok
                (4, 0), // duplicate within batch
            ],
        };
        let v = validate(&g, &b);
        assert_eq!(v.clean.deletions, vec![]);
        assert_eq!(v.clean.insertions, vec![(4, 0)]);
        assert_eq!(v.quarantined(), 7);
        let errs: Vec<UpdateError> = v.rejections.iter().map(|r| r.error).collect();
        assert_eq!(
            errs,
            vec![
                UpdateError::OutOfRange { num_vertices: 5 },
                UpdateError::SelfLoop,
                UpdateError::PhantomDeletion,
                UpdateError::OutOfRange { num_vertices: 5 },
                UpdateError::SelfLoop,
                UpdateError::DuplicateInsertion,
                UpdateError::DuplicateInsertion,
            ]
        );
        let s = v.summary();
        assert!(s.contains("quarantined 7/8"), "{s}");
        assert!(s.contains("out-of-range=2"), "{s}");
        assert!(s.contains("self-loop=2"), "{s}");
        assert!(s.contains("duplicate-insertion=2"), "{s}");
        assert!(s.contains("phantom-deletion=1"), "{s}");
    }

    #[test]
    fn intra_batch_delete_then_reinsert_is_clean() {
        let g = graph();
        // (0,1) exists: deleting then re-inserting it in one batch is legal
        // under apply order (deletions first), so both edits pass.
        let b = BatchUpdate { deletions: vec![(0, 1)], insertions: vec![(0, 1)] };
        let v = validate(&g, &b);
        assert!(v.is_fully_clean());
        // but inserting an edge that was never there, "covered" by a phantom
        // deletion of the same edge, quarantines only the deletion
        let b = BatchUpdate { deletions: vec![(3, 0)], insertions: vec![(3, 0)] };
        let v = validate(&g, &b);
        assert_eq!(v.clean.deletions, vec![]);
        assert_eq!(v.clean.insertions, vec![(3, 0)]);
        assert_eq!(v.rejections[0].error, UpdateError::PhantomDeletion);
    }

    #[test]
    fn double_deletion_second_is_phantom() {
        let g = graph();
        let b = BatchUpdate { deletions: vec![(0, 1), (0, 1)], insertions: vec![] };
        let v = validate(&g, &b);
        assert_eq!(v.clean.deletions, vec![(0, 1)]);
        assert_eq!(v.rejections.len(), 1);
        assert_eq!(v.rejections[0].error, UpdateError::PhantomDeletion);
    }

    #[test]
    fn clean_subset_applies_without_panic_and_fully() {
        let mut g = er::generate(100, 4.0, 11);
        g.ensure_self_loops();
        let b = BatchUpdate {
            deletions: vec![(0, 0), (1_000, 3), (2, 1_000_000)],
            insertions: vec![(7, 7), (500, 1), (1, 500)],
        };
        let v = validate(&g, &b);
        assert!(v.clean.is_empty() || v.clean.len() < b.len());
        let changed = batch::apply(&mut g, &v.clean);
        assert_eq!(changed, v.clean.len(), "clean subset applies exactly");
    }

    #[test]
    fn batch_that_empties_the_graph_leaves_uniform_ranks() {
        // deleting every real edge is a legal batch: the protected
        // self-loops remain, so the result is n disconnected vertices with
        // exactly uniform PageRank 1/n.
        let mut g = graph();
        let b = BatchUpdate { deletions: g.real_edges(), insertions: vec![] };
        let v = validate(&g, &b);
        assert!(v.is_fully_clean(), "{:?}", v.rejections);
        let changed = batch::apply(&mut g, &v.clean);
        assert_eq!(changed, 3);
        assert!(g.real_edges().is_empty());
        let mut fresh = GraphBuilder::new(5);
        fresh.ensure_self_loops();
        assert_eq!(g.to_csr(), fresh.to_csr(), "only self-loops left");

        let csr = g.to_csr();
        let gt = csr.transpose();
        let cfg = crate::engines::config::PagerankConfig::default();
        let res = crate::engines::native::static_pagerank(&csr, &gt, &cfg, None);
        assert_eq!(res.iterations, 1, "uniform fixed point from the start");
        for r in &res.ranks {
            assert!((r - 0.2).abs() < 1e-12, "rank {r} != 1/5");
        }
    }

    #[test]
    fn applied_subset_matches_from_scratch_rebuild() {
        // delete-then-insert of the same edge plus duplicates on both
        // halves: the clean subset must land on exactly the edge set a
        // fresh builder of the intended final graph has.
        let mut g = graph();
        let b = BatchUpdate {
            deletions: vec![(0, 1), (0, 1), (1, 2)],
            insertions: vec![(0, 1), (3, 4), (3, 4)],
        };
        let v = validate(&g, &b);
        assert_eq!(v.quarantined(), 2, "{:?}", v.rejections);
        assert_eq!(v.rejections[0].error, UpdateError::PhantomDeletion);
        assert_eq!(v.rejections[1].error, UpdateError::DuplicateInsertion);
        let changed = batch::apply(&mut g, &v.clean);
        assert_eq!(changed, v.clean.len());

        let mut want = GraphBuilder::from_edges(5, [(0, 1), (2, 3), (3, 4)]);
        want.ensure_self_loops();
        assert_eq!(g.to_csr(), want.to_csr(), "matches from-scratch rebuild");
    }

    #[test]
    fn validate_random_batches_are_always_clean() {
        let g = er::generate(300, 5.0, 3);
        for seed in 0..5 {
            let b = batch::random_batch(&g, 40, 0.8, seed);
            let v = validate(&g, &b);
            assert!(v.is_fully_clean(), "seed {seed}: {:?}", v.rejections);
        }
    }
}
