//! Batch updates: the unit of change in a dynamic graph (paper Section 3.3),
//! plus the random batch generator of Section 5.1.4 (80% insertions / 20%
//! deletions, vertex pairs uniform, deletions uniform over existing edges).

pub mod validate;

use crate::graph::{GraphBuilder, VertexId};
use crate::util::Rng;

pub use validate::{validate, EditKind, Rejection, UpdateError, ValidatedBatch};

/// A batch update Δ^t: edge deletions Δ^t- and insertions Δ^t+.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct BatchUpdate {
    pub deletions: Vec<(VertexId, VertexId)>,
    pub insertions: Vec<(VertexId, VertexId)>,
}

impl BatchUpdate {
    pub fn len(&self) -> usize {
        self.deletions.len() + self.insertions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Every vertex touched by the update (sources and targets).
    pub fn touched(&self) -> impl Iterator<Item = VertexId> + '_ {
        self.deletions
            .iter()
            .chain(self.insertions.iter())
            .flat_map(|&(u, v)| [u, v])
    }
}

/// Generate a random batch of `size` edge updates against `g`, with
/// `ins_frac` insertions (paper: 0.8) and the rest deletions. Insertions
/// pick vertex pairs uniformly (skipping existing edges and self-pairs);
/// deletions pick uniformly among existing non-self-loop edges. No vertices
/// are added or removed (Section 5.1.4).
pub fn random_batch(g: &GraphBuilder, size: usize, ins_frac: f64, seed: u64) -> BatchUpdate {
    let mut rng = Rng::seed_from_u64(seed);
    let n = g.num_vertices();
    let n_ins = (size as f64 * ins_frac).round() as usize;
    let n_del = size - n_ins;

    let mut insertions = Vec::with_capacity(n_ins);
    let mut attempts = 0;
    while insertions.len() < n_ins && attempts < n_ins * 20 + 100 {
        attempts += 1;
        let u = rng.gen_range(n) as VertexId;
        let v = rng.gen_range(n) as VertexId;
        if u != v && !g.has_edge(u, v) {
            insertions.push((u, v));
        }
    }

    let mut real = g.real_edges();
    rng.shuffle(&mut real);
    let deletions = real.into_iter().take(n_del).collect();

    BatchUpdate { deletions, insertions }
}

/// Apply the batch to the builder and re-add self-loops (the paper adds
/// self-loops to all vertices alongside each batch update). Returns the
/// number of edges actually changed.
pub fn apply(g: &mut GraphBuilder, batch: &BatchUpdate) -> usize {
    let mut changed = 0;
    for &(u, v) in &batch.deletions {
        changed += g.remove_edge(u, v) as usize;
    }
    for &(u, v) in &batch.insertions {
        changed += g.insert_edge(u, v) as usize;
    }
    g.ensure_self_loops();
    changed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generators::er;

    #[test]
    fn respects_mix_and_size() {
        let g = er::generate(300, 6.0, 5);
        let b = random_batch(&g, 100, 0.8, 7);
        assert_eq!(b.insertions.len(), 80);
        assert_eq!(b.deletions.len(), 20);
        for &(u, v) in &b.insertions {
            assert!(u != v && !g.has_edge(u, v));
        }
        for &(u, v) in &b.deletions {
            assert!(u != v && g.has_edge(u, v));
        }
    }

    #[test]
    fn apply_changes_graph_and_keeps_self_loops() {
        let mut g = er::generate(200, 4.0, 1);
        let m0 = g.num_edges();
        let b = random_batch(&g, 50, 0.8, 2);
        let changed = apply(&mut g, &b);
        assert_eq!(changed, b.len());
        assert_eq!(g.num_edges(), m0 + b.insertions.len() - b.deletions.len());
        assert!(g.to_csr().has_no_dead_ends());
    }

    #[test]
    fn deterministic_per_seed() {
        let g = er::generate(200, 4.0, 1);
        assert_eq!(random_batch(&g, 30, 0.8, 9), random_batch(&g, 30, 0.8, 9));
        assert_ne!(random_batch(&g, 30, 0.8, 9), random_batch(&g, 30, 0.8, 10));
    }

    #[test]
    fn touched_covers_all_endpoints() {
        let g = er::generate(100, 4.0, 3);
        let b = random_batch(&g, 20, 0.5, 4);
        let touched: std::collections::HashSet<_> = b.touched().collect();
        for &(u, v) in b.deletions.iter().chain(&b.insertions) {
            assert!(touched.contains(&u) && touched.contains(&v));
        }
    }
}
