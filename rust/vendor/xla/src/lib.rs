//! API-compatible stub of the `xla` PJRT bindings used by `runtime::`.
//!
//! The offline build has no PJRT shared library and no registry access, so
//! this crate mirrors exactly the type/method surface the workspace calls
//! and reports the runtime as unavailable at the earliest entry point
//! (`PjRtClient::cpu`). Everything downstream of a client therefore never
//! executes, but still type-checks, keeping the device engine, artifact
//! store, and device tests compiling; they gracefully skip at run time.
//! Swapping in the real bindings is a Cargo.toml change only.

use std::fmt;
use std::path::Path;

/// Error type mirroring `xla::Error`: displayable, usable with `?` into
/// `anyhow::Error` via the std-error blanket conversion.
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    fn unavailable(what: &str) -> Self {
        Self {
            msg: format!(
                "{what}: XLA/PJRT runtime unavailable in this offline build \
                 (vendored stub; install the real xla bindings to enable the device path)"
            ),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Stub PJRT client. `cpu()` always fails in the offline build.
#[derive(Debug)]
#[non_exhaustive]
pub struct PjRtClient {}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(Error::unavailable("buffer_from_host_buffer"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("compile"))
    }
}

/// Stub device buffer (never constructed in the offline build).
#[derive(Debug)]
#[non_exhaustive]
pub struct PjRtBuffer {}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("to_literal_sync"))
    }
}

/// Stub compiled executable (never constructed in the offline build).
#[derive(Debug)]
#[non_exhaustive]
pub struct PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("execute"))
    }

    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("execute_b"))
    }
}

/// Stub host literal. Constructible (the lit helpers build these before any
/// device call), but all conversions report the runtime as unavailable.
#[derive(Debug, Clone)]
#[non_exhaustive]
pub struct Literal {}

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal {}
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::unavailable("reshape"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("to_vec"))
    }

    pub fn shape(&self) -> Result<Shape> {
        Err(Error::unavailable("shape"))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("to_tuple"))
    }
}

/// Literal/result shapes (only the tuple-ness is ever inspected).
#[derive(Debug, Clone)]
pub enum Shape {
    Tuple(Vec<Shape>),
    Array,
}

/// Stub HLO module proto; parsing always fails in the offline build.
#[derive(Debug)]
#[non_exhaustive]
pub struct HloModuleProto {}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<Self> {
        Err(Error::unavailable(&format!(
            "HloModuleProto::from_text_file({})",
            path.as_ref().display()
        )))
    }
}

/// Stub computation wrapper.
#[derive(Debug)]
#[non_exhaustive]
pub struct XlaComputation {}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("unavailable"));
    }

    #[test]
    fn literal_constructs_but_does_not_convert() {
        let lit = Literal::vec1(&[1.0f64, 2.0]);
        assert!(lit.to_vec::<f64>().is_err());
        assert!(lit.clone().to_tuple().is_err());
        assert!(lit.shape().is_err());
    }
}
