//! Vendored minimal stand-in for the `anyhow` crate (offline build: no
//! registry access). Implements exactly the surface this workspace uses:
//! [`Error`], [`Result`], the `anyhow!` / `bail!` / `ensure!` macros, and
//! the [`Context`] extension trait for `Result` and `Option`.
//!
//! Like the real crate, [`Error`] deliberately does **not** implement
//! `std::error::Error`; that is what makes the blanket
//! `From<E: std::error::Error>` impl coherent, so `?` converts any standard
//! error (I/O, parse, ...) into [`Error`].

use std::fmt;

/// A string-backed error value with a flattened context chain.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable (the `anyhow!` macro calls this).
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Self { msg: m.to_string() }
    }

    /// Prepend a context layer, anyhow-style (`"context: cause"`).
    pub fn wrap<C: fmt::Display>(self, context: C) -> Self {
        Self { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>`: a `Result` defaulting its error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::Error::msg(::std::format!($($arg)*)))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(::std::format!($($arg)*)));
        }
    };
}

/// Attach context to errors (`Result`) or missing values (`Option`).
pub trait Context<T> {
    /// Wrap the error/none case with a context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Wrap with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<i32> {
        let x: i32 = s.parse()?; // exercises the blanket From impl
        Ok(x)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn macros_and_context() {
        let e = anyhow!("bad {}", 7);
        assert_eq!(e.to_string(), "bad 7");

        fn f() -> Result<()> {
            bail!("inner {}", "cause");
        }
        let wrapped = f().context("outer");
        assert_eq!(wrapped.unwrap_err().to_string(), "outer: inner cause");

        let missing: Option<u8> = None;
        let got = missing.with_context(|| format!("no {}", "value"));
        assert_eq!(got.unwrap_err().to_string(), "no value");

        fn g(ok: bool) -> Result<u8> {
            ensure!(ok, "must hold ({ok})");
            Ok(1)
        }
        assert!(g(true).is_ok());
        assert!(g(false).is_err());
    }
}
