//! Baseline drift gate: the Gunrock-like and Hornet-like comparators must
//! keep converging to the same fixed point as the native static engine on
//! every generator family, with a sane iteration count.
//!
//! The speedup claims in EXPERIMENTS.md compare wall-clock against these
//! baselines; if a refactor ever changed *what* a baseline computes (not
//! just how fast), the comparison would silently measure two different
//! problems. These tests pin rank agreement (L1 and L∞ against the native
//! engine at the same configuration) and iteration-count proximity, so any
//! algorithmic drift in a baseline fails loudly.

use pagerank_dynamic::engines::baselines::{gunrock_like, hornet_like};
use pagerank_dynamic::engines::error::{l1_distance, linf_distance};
use pagerank_dynamic::engines::native::static_pagerank;
use pagerank_dynamic::generators::{chain, er, grid, rmat};
use pagerank_dynamic::graph::GraphBuilder;
use pagerank_dynamic::PagerankConfig;

/// The four generator families of the determinism matrix. Self-loops are
/// required: the Hornet baseline divides by out-degree with no dead-end
/// guard (faithful to the modeled framework, which assumes them).
fn generators() -> Vec<(&'static str, GraphBuilder)> {
    let mut gens = vec![
        ("chain", chain::generate(1_500, 30, 5)),
        ("grid", grid::generate(30, 40, 7)),
        ("er", er::generate(1_800, 6.0, 11)),
        ("rmat-web", rmat::generate(11, 8.0, rmat::RmatParams::WEB, 13)),
    ];
    for (_, b) in gens.iter_mut() {
        b.ensure_self_loops();
    }
    gens
}

#[test]
fn baselines_agree_with_native_static_on_all_families() {
    let cfg = PagerankConfig::default();
    for (gname, b) in generators() {
        let g = b.to_csr();
        let gt = g.transpose();
        let native = static_pagerank(&g, &gt, &cfg, None);
        for (bname, res) in [
            ("gunrock", gunrock_like(&g, &cfg)),
            ("hornet", hornet_like(&g, &cfg)),
        ] {
            let l1 = l1_distance(&res.ranks, &native.ranks).unwrap();
            let linf = linf_distance(&res.ranks, &native.ranks).unwrap();
            assert!(
                l1 < 1e-5,
                "{gname}/{bname}: L1 drift {l1:.3e} from native static"
            );
            assert!(
                linf < 1e-8,
                "{gname}/{bname}: L∞ drift {linf:.3e} from native static"
            );
            assert!(
                (res.ranks.iter().sum::<f64>() - 1.0).abs() < 1e-6,
                "{gname}/{bname}: rank mass not 1"
            );
        }
    }
}

#[test]
fn baseline_iteration_counts_stay_sane() {
    // Same damping, same tolerance, same synchronous update → the baselines
    // walk the same power iteration and must land within a couple of
    // iterations of the native engine (their norms differ only in
    // reduction shape), well before the cap. A baseline suddenly
    // converging much faster or hitting the cap means it is no longer
    // computing the same thing.
    let cfg = PagerankConfig::default();
    for (gname, b) in generators() {
        let g = b.to_csr();
        let gt = g.transpose();
        let native = static_pagerank(&g, &gt, &cfg, None);
        for (bname, res) in [
            ("gunrock", gunrock_like(&g, &cfg)),
            ("hornet", hornet_like(&g, &cfg)),
        ] {
            assert!(
                res.iterations < cfg.max_iterations,
                "{gname}/{bname}: hit the iteration cap"
            );
            let diff = res.iterations.abs_diff(native.iterations);
            assert!(
                diff <= 2,
                "{gname}/{bname}: {} iterations vs native {}",
                res.iterations,
                native.iterations
            );
        }
    }
}
