//! Differential batch-sequence fuzz: seeded random insert/delete batches
//! replayed through every incremental approach (ND, DT, DF, DF-P), each
//! asserted against a from-scratch Static recompute of the same snapshot.
//!
//! Each approach chains its *own* previous ranks from step to step (the
//! production shape: an incremental engine never sees a clean static
//! restart), so the tolerances below bound accumulated drift over the whole
//! sequence, not a single update. On a mismatch the failing seed and step
//! are printed together with a greedily minimized batch — the smallest
//! subset of the step's edits that still reproduces the divergence — so a
//! regression lands as a ready-made reproducer, not a 12-edit haystack.

use pagerank_dynamic::batch::{self, BatchUpdate};
use pagerank_dynamic::engines::error::l1_distance;
use pagerank_dynamic::engines::native::dynamic::{dynamic_frontier, dynamic_traversal};
use pagerank_dynamic::engines::native::{naive_dynamic, static_pagerank};
use pagerank_dynamic::generators::er;
use pagerank_dynamic::graph::GraphBuilder;
use pagerank_dynamic::{CsrGraph, PagerankConfig};

const SEEDS: [u64; 3] = [3, 17, 202];
const STEPS: usize = 6;
const BATCH_SIZE: usize = 12;

/// Accumulated-L1 budget per approach over the whole chained sequence. DT
/// re-iterates everything reachable (tight); DF/DF-P stop propagating below
/// the frontier tolerance, so their drift budget is the loosest.
fn tolerance(approach: &str) -> f64 {
    match approach {
        "nd" => 1e-6,
        "dt" => 1e-4,
        "df" | "dfp" => 5e-3,
        _ => unreachable!("unknown approach {approach}"),
    }
}

fn run_approach(
    approach: &str,
    g: &CsrGraph,
    gt: &CsrGraph,
    old_g: &CsrGraph,
    cfg: &PagerankConfig,
    prev: &[f64],
    upd: &BatchUpdate,
) -> Vec<f64> {
    match approach {
        "nd" => naive_dynamic(g, gt, cfg, prev).ranks,
        "dt" => dynamic_traversal(g, gt, old_g, cfg, prev, upd).ranks,
        "df" => dynamic_frontier(g, gt, cfg, prev, upd, false).ranks,
        "dfp" => dynamic_frontier(g, gt, cfg, prev, upd, true).ranks,
        _ => unreachable!("unknown approach {approach}"),
    }
}

/// L1 error of `approach` against a from-scratch static recompute after
/// applying `upd` to (a clone of) `before`.
fn divergence(
    approach: &str,
    before: &GraphBuilder,
    prev: &[f64],
    upd: &BatchUpdate,
    cfg: &PagerankConfig,
) -> f64 {
    let old_g = before.to_csr();
    let mut b = before.clone();
    batch::apply(&mut b, upd);
    let g = b.to_csr();
    let gt = g.transpose();
    let got = run_approach(approach, &g, &gt, &old_g, cfg, prev, upd);
    let want = static_pagerank(&g, &gt, cfg, None).ranks;
    l1_distance(&got, &want).unwrap()
}

/// Greedy one-edit minimization: repeatedly drop any single deletion or
/// insertion whose removal keeps the divergence above tolerance, until no
/// single removal does. The result is a locally minimal reproducer.
fn minimize_batch(
    approach: &str,
    before: &GraphBuilder,
    prev: &[f64],
    upd: &BatchUpdate,
    cfg: &PagerankConfig,
) -> BatchUpdate {
    let tol = tolerance(approach);
    let mut cur = upd.clone();
    loop {
        let mut shrunk = false;
        let mut i = 0;
        while i < cur.deletions.len() {
            let mut cand = cur.clone();
            cand.deletions.remove(i);
            if divergence(approach, before, prev, &cand, cfg) >= tol {
                cur = cand;
                shrunk = true;
            } else {
                i += 1;
            }
        }
        let mut i = 0;
        while i < cur.insertions.len() {
            let mut cand = cur.clone();
            cand.insertions.remove(i);
            if divergence(approach, before, prev, &cand, cfg) >= tol {
                cur = cand;
                shrunk = true;
            } else {
                i += 1;
            }
        }
        if !shrunk {
            return cur;
        }
    }
}

#[test]
fn incremental_approaches_track_static_over_batch_sequences() {
    let cfg = PagerankConfig::default();
    for seed in SEEDS {
        let mut b = er::generate(400, 5.0, seed);
        b.ensure_self_loops();
        let g0 = b.to_csr();
        let gt0 = g0.transpose();
        let r0 = static_pagerank(&g0, &gt0, &cfg, None).ranks;

        // each approach carries its own chained prev
        let approaches = ["nd", "dt", "df", "dfp"];
        let mut prevs: Vec<Vec<f64>> = approaches.iter().map(|_| r0.clone()).collect();

        for step in 0..STEPS {
            let before = b.clone();
            let upd = batch::random_batch(&b, BATCH_SIZE, 0.7, seed * 1000 + step as u64);
            batch::apply(&mut b, &upd);
            let g = b.to_csr();
            let gt = g.transpose();
            let old_g = before.to_csr();
            let want = static_pagerank(&g, &gt, &cfg, None).ranks;

            for (a, approach) in approaches.iter().enumerate() {
                let got =
                    run_approach(approach, &g, &gt, &old_g, &cfg, &prevs[a], &upd);
                let err = l1_distance(&got, &want).unwrap();
                let tol = tolerance(approach);
                if err >= tol {
                    let min = minimize_batch(approach, &before, &prevs[a], &upd, &cfg);
                    panic!(
                        "{approach} diverged from static: seed={seed} step={step} \
                         l1={err:.3e} (tol {tol:.0e})\n\
                         minimized batch ({} deletions, {} insertions):\n\
                         deletions: {:?}\ninsertions: {:?}",
                        min.deletions.len(),
                        min.insertions.len(),
                        min.deletions,
                        min.insertions,
                    );
                }
                prevs[a] = got;
            }
        }
    }
}

/// Minimizer sanity on both ends of the spectrum, plus side-effect freedom
/// of the probing. A divergence that survives *every* removal must shrink
/// all the way to the empty batch; a batch that never diverges must keep
/// every edit (no removal reproduces a failure, so nothing may be dropped).
#[test]
fn minimizer_converges_and_leaves_the_builder_untouched() {
    let cfg = PagerankConfig::default();

    // Always-diverging case, by construction: two components — a symmetric
    // ring (vertices 0..100) and a star (100..200) whose true ranks are far
    // from uniform — with a stale uniform `prev` and batch edits confined
    // to the ring. DF's frontier can never cross into the star, so its
    // vertices keep their (wrong) stale ranks for every sub-batch,
    // including the empty one, and the greedy loop must strip everything.
    let n = 200u32;
    let mut edges: Vec<(u32, u32)> = (0..n).map(|v| (v, v)).collect();
    edges.extend((0..100).map(|v| (v, (v + 1) % 100)));
    edges.extend((101..n).map(|v| (v, 100)));
    let b = GraphBuilder::from_edges(n as usize, edges);
    let g0 = b.to_csr();
    let stale = vec![1.0 / n as f64; n as usize];
    let upd = BatchUpdate {
        insertions: vec![(3, 50), (10, 70)],
        deletions: vec![(5, 6), (20, 21)],
    };
    assert!(divergence("df", &b, &stale, &BatchUpdate::default(), &cfg) >= tolerance("df"));
    let min = minimize_batch("df", &b, &stale, &upd, &cfg);
    assert!(min.deletions.is_empty() && min.insertions.is_empty());

    // Never-diverging case: a converged prev — ND re-converges on every
    // sub-batch, so no removal keeps a failure alive and nothing is dropped.
    let gt0 = g0.transpose();
    let prev = static_pagerank(&g0, &gt0, &cfg, None).ranks;
    let kept = minimize_batch("nd", &b, &prev, &upd, &cfg);
    assert_eq!(kept.deletions, upd.deletions);
    assert_eq!(kept.insertions, upd.insertions);

    // and the builder was never mutated by any of the probing
    assert_eq!(b.to_csr(), g0);
}
