//! Cross-layer golden test: the Python oracle (`python/compile/kernels/
//! ref.py::naive_pagerank`) produced these ranks for a fixed 8-vertex
//! graph; every Rust engine (native sync/async, device via artifacts) must
//! reproduce them. This pins the L1↔L2↔L3 numerical contract across
//! languages — if either side's formula drifts, this fails.

use std::path::PathBuf;

use pagerank_dynamic::engines::native::{self, asynchronous};
use pagerank_dynamic::engines::device::DeviceEngine;
use pagerank_dynamic::graph::CsrGraph;
use pagerank_dynamic::runtime::ArtifactStore;
use pagerank_dynamic::PagerankConfig;

/// Graph (self-loops included): v -> [neighbors]; mirrored in the python
/// snippet in this file's history / EXPERIMENTS.md.
fn golden_graph() -> CsrGraph {
    CsrGraph::from_adjacency(&[
        vec![0, 1, 2],
        vec![1, 3],
        vec![2, 3, 0],
        vec![3, 4],
        vec![4, 0, 5],
        vec![5, 6],
        vec![6, 7, 0],
        vec![7, 2],
    ])
}

/// Output of `ref.naive_pagerank` (alpha=0.85, tau=1e-10, L-inf), 41 iters.
const GOLDEN: [f64; 8] = [
    1.676353592250898e-1,
    1.152116262848269e-1,
    1.366786376910401e-1,
    1.851140089784086e-1,
    1.359397029428229e-1,
    9.959347678558501e-2,
    8.522403858254061e-2,
    7.460314950968594e-2,
];
const GOLDEN_ITERS: usize = 41;

#[test]
fn native_sync_matches_python_oracle() {
    let g = golden_graph();
    let gt = g.transpose();
    let res = native::static_pagerank(&g, &gt, &PagerankConfig::default(), None);
    assert_eq!(res.iterations, GOLDEN_ITERS);
    for (got, want) in res.ranks.iter().zip(GOLDEN) {
        assert!((got - want).abs() < 1e-12, "{got} vs {want}");
    }
}

#[test]
fn native_async_matches_python_oracle() {
    let g = golden_graph();
    let gt = g.transpose();
    let res = asynchronous::static_async(&g, &gt, &PagerankConfig::default(), None);
    for (got, want) in res.ranks.iter().zip(GOLDEN) {
        assert!((got - want).abs() < 1e-9, "{got} vs {want}");
    }
}

#[test]
fn device_matches_python_oracle() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: no artifacts at {} (run `make artifacts`)", dir.display());
        return;
    }
    let store = ArtifactStore::open(&dir).expect("artifacts load");
    let g = golden_graph();
    let gt = g.transpose();
    let dg = store.pack_graph(&g, &gt).unwrap();
    let res = DeviceEngine::new(&store)
        .static_pagerank(&dg, &PagerankConfig::default(), None)
        .unwrap();
    assert_eq!(res.iterations, GOLDEN_ITERS);
    for (got, want) in res.ranks.iter().zip(GOLDEN) {
        assert!((got - want).abs() < 1e-12, "{got} vs {want}");
    }
}
