//! Integration: the serving coordinator over the device engine — the full
//! L3 request path (updates, policy, queries, metrics) with artifacts.

use std::path::PathBuf;
use std::sync::Arc;

use pagerank_dynamic::batch::{random_batch, BatchUpdate};
use pagerank_dynamic::coordinator::server::spawn;
use pagerank_dynamic::coordinator::DynamicGraphService;
use pagerank_dynamic::engines::error::{l1_distance, reference_ranks};
use pagerank_dynamic::engines::Approach;
use pagerank_dynamic::generators::er;
use pagerank_dynamic::runtime::ArtifactStore;
use pagerank_dynamic::temporal;
use pagerank_dynamic::PagerankConfig;

/// Artifact store, or `None` on checkouts without compiled artifacts
/// (tests skip; `make artifacts` produces them).
fn open_store() -> Option<Arc<ArtifactStore>> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: no artifacts at {} (run `make artifacts`)", dir.display());
        return None;
    }
    Some(Arc::new(ArtifactStore::open(&dir).expect("artifacts load")))
}

#[test]
fn device_backed_service_tracks_reference() {
    let Some(store) = open_store() else { return };
    let mut service = DynamicGraphService::new(
        er::generate(700, 5.0, 3),
        Some(store),
        PagerankConfig::default(),
    );
    // test graphs are small; widen the DF-P regime so 2-edge batches on a
    // ~4k-edge graph still select DF-P (paper threshold is 1e-4|E|)
    service.policy.config.nd_batch_fraction = 1e-2;
    let first = service.ensure_ranks().unwrap();
    assert_eq!(first.approach, Approach::Static);
    assert!(first.on_device, "graph fits t10/t13 -> device path");

    let mut batches_applied = 0;
    for seed in 0..4u64 {
        let b = random_batch_for(&service, 2, seed);
        let rep = service.apply_update(b).unwrap();
        assert!(rep.on_device);
        assert_eq!(rep.approach, Approach::DynamicFrontierPruning);
        batches_applied += 1;
    }
    assert_eq!(service.metrics.updates_applied, 1 + batches_applied);
    assert_eq!(service.metrics.native_fallbacks, 0);
}

fn random_batch_for(
    s: &DynamicGraphService,
    size: usize,
    seed: u64,
) -> BatchUpdate {
    // rebuild a builder view: the service owns it privately, so generate
    // against a same-seed copy — only insertion endpoints matter here.
    let mut b = pagerank_dynamic::graph::GraphBuilder::new(s.num_vertices());
    b.ensure_self_loops();
    random_batch(&b, size, 1.0, seed) // insertion-only, guaranteed-new edges
}

#[test]
fn served_replay_end_to_end() {
    // the wiki-talk-style stand-in, scaled down for the test
    let tg = temporal::generate("test-stream", 900, 24_000, 0.4, 17);
    let bsize = 24; // 1e-3 |E_T|
    let (base, batches) = tg.replay(bsize, 6);

    let Some(store) = open_store() else { return };
    let h = spawn(move || {
        DynamicGraphService::new(base, Some(store), PagerankConfig::default())
    });
    let init = h.update(BatchUpdate::default()).unwrap();
    assert!(init.iterations > 0 && init.on_device);

    for upd in batches {
        let rep = h.update(upd).unwrap();
        assert!(rep.on_device, "stays on device path");
        assert!(rep.iterations <= 500);
    }
    let stats = h.stats().unwrap();
    assert!(stats.contains("updates=7"), "{stats}");
    let top = h.top_k(5).unwrap();
    assert_eq!(top.len(), 5);
    assert!(top.windows(2).all(|w| w[0].1 >= w[1].1));
}

#[test]
fn policy_error_guard_switches_to_nd() {
    let Some(store) = open_store() else { return };
    let mut service = DynamicGraphService::new(
        er::generate(600, 5.0, 9),
        Some(store),
        PagerankConfig::default(),
    );
    service.policy.config.nd_batch_fraction = 1e-2;
    service.ensure_ranks().unwrap();
    service.policy.observe_error(1.0); // trip the guard
    let b = BatchUpdate { deletions: vec![], insertions: vec![(1, 5)] };
    let rep = service.apply_update(b).unwrap();
    assert_eq!(rep.approach, Approach::NaiveDynamic);

    // a static refresh resets the guard
    service.refresh_static().unwrap();
    let b = BatchUpdate { deletions: vec![], insertions: vec![(2, 9)] };
    let rep = service.apply_update(b).unwrap();
    assert_eq!(rep.approach, Approach::DynamicFrontierPruning);
}

#[test]
fn long_update_sequence_stays_accurate() {
    // accuracy over a long DF-P sequence (the paper's per-batch figures):
    // accumulated drift must stay within the acceptability band.
    let Some(store) = open_store() else { return };
    let mut service = DynamicGraphService::new(
        er::generate(500, 5.0, 21),
        Some(store),
        PagerankConfig::default(),
    );
    service.ensure_ranks().unwrap();
    let mut shadow = er::generate(500, 5.0, 21);
    shadow.ensure_self_loops();

    for seed in 0..10u64 {
        let upd = random_batch(&shadow, 2, 0.8, 1000 + seed);
        pagerank_dynamic::batch::apply(&mut shadow, &upd);
        service.apply_update(upd).unwrap();
    }
    let g = shadow.to_csr();
    let gt = g.transpose();
    let truth = reference_ranks(&g, &gt);
    let err = l1_distance(service.ranks().unwrap(), &truth).unwrap();
    assert!(err < 5e-3, "accumulated error {err}");
}
