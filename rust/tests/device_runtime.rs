//! Integration: AOT artifacts → PJRT → device engines vs native engines.
//!
//! These tests require `make artifacts` to have run (the repo's Makefile
//! test target guarantees it).

use std::path::PathBuf;

use pagerank_dynamic::batch::{self, BatchUpdate};
use pagerank_dynamic::engines::device::{DeviceEngine, PartitionMode};
use pagerank_dynamic::engines::error::l1_distance;
use pagerank_dynamic::engines::{native, Approach};
use pagerank_dynamic::generators::{er, rmat};
use pagerank_dynamic::runtime::{ArtifactStore, DeviceGraph};
use pagerank_dynamic::PagerankConfig;

/// Artifact store, or `None` on checkouts without compiled artifacts
/// (tests skip; `make artifacts` produces them).
fn store() -> Option<ArtifactStore> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: no artifacts at {} (run `make artifacts`)", dir.display());
        return None;
    }
    Some(ArtifactStore::open(&dir).expect("artifacts load"))
}

fn pack(
    b: &pagerank_dynamic::graph::GraphBuilder,
    store: &ArtifactStore,
) -> (pagerank_dynamic::CsrGraph, pagerank_dynamic::CsrGraph, DeviceGraph) {
    let g = b.to_csr();
    let gt = g.transpose();
    let tier = store.tier_for(g.num_vertices(), g.num_edges()).unwrap();
    let dg = DeviceGraph::pack(&g, &gt, &tier).unwrap();
    (g, gt, dg)
}

#[test]
fn device_static_matches_native() {
    let Some(store) = store() else { return };
    let eng = DeviceEngine::new(&store);
    let cfg = PagerankConfig::default();
    for b in [
        er::generate(300, 5.0, 1),
        rmat::generate(9, 8.0, rmat::RmatParams::WEB, 2), // exercises hubs
    ] {
        let (g, gt, dg) = pack(&b, &store);
        let dev = eng.static_pagerank(&dg, &cfg, None).unwrap();
        let nat = native::static_pagerank(&g, &gt, &cfg, None);
        assert_eq!(dev.iterations, nat.iterations);
        assert!(
            l1_distance(&dev.ranks, &nat.ranks).unwrap() < 1e-9,
            "device vs native static"
        );
        assert!((dev.ranks.iter().sum::<f64>() - 1.0).abs() < 1e-6);
    }
}

#[test]
fn device_dynamic_approaches_match_native() {
    let Some(store) = store() else { return };
    let eng = DeviceEngine::new(&store);
    let cfg = PagerankConfig::default();

    let mut b = rmat::generate(9, 6.0, rmat::RmatParams::SOCIAL, 7);
    let old_g = b.to_csr();
    let old_gt = old_g.transpose();
    let prev = native::static_pagerank(&old_g, &old_gt, &cfg, None).ranks;

    let upd = batch::random_batch(&b, 12, 0.8, 5);
    batch::apply(&mut b, &upd);
    let (g, gt, dg) = pack(&b, &store);

    // ND
    let dev = eng.naive_dynamic(&dg, &cfg, &prev).unwrap();
    let nat = native::naive_dynamic(&g, &gt, &cfg, &prev);
    assert!(l1_distance(&dev.ranks, &nat.ranks).unwrap() < 1e-9, "ND");
    assert_eq!(dev.iterations, nat.iterations, "ND iterations");

    // DT
    let dev = eng.dynamic_traversal(&dg, &g, &old_g, &cfg, &prev, &upd).unwrap();
    let nat = native::dynamic::dynamic_traversal(&g, &gt, &old_g, &cfg, &prev, &upd);
    assert!(l1_distance(&dev.ranks, &nat.ranks).unwrap() < 1e-9, "DT");
    assert_eq!(dev.initially_affected, nat.initially_affected);

    // DF / DF-P across every partition mode and worklist setting
    for prune in [false, true] {
        let nat = native::dynamic::dynamic_frontier(&g, &gt, &cfg, &prev, &upd, prune);
        for mode in [
            PartitionMode::DontPartition,
            PartitionMode::PartitionGPrime,
            PartitionMode::PartitionBoth,
            PartitionMode::PartitionBothPull,
        ] {
            for wl in [false, true] {
                let dev = eng
                    .dynamic_frontier(&dg, &g, &cfg, &prev, &upd, prune, mode, wl)
                    .unwrap();
                assert!(
                    l1_distance(&dev.ranks, &nat.ranks).unwrap() < 1e-9,
                    "prune={prune} mode={mode:?} wl={wl}"
                );
                assert_eq!(
                    dev.iterations, nat.iterations,
                    "prune={prune} mode={mode:?} wl={wl}"
                );
                assert_eq!(dev.initially_affected, nat.initially_affected);
            }
        }
    }
}

#[test]
fn device_empty_batch_noop() {
    let Some(store) = store() else { return };
    let eng = DeviceEngine::new(&store);
    let cfg = PagerankConfig::default();
    let b = er::generate(200, 4.0, 3);
    let (g, gt, dg) = pack(&b, &store);
    let prev = native::static_pagerank(&g, &gt, &cfg, None).ranks;
    let res = eng
        .dynamic_frontier(
            &dg,
            &g,
            &cfg,
            &prev,
            &BatchUpdate::default(),
            true,
            PartitionMode::PartitionBothPull,
            true,
        )
        .unwrap();
    assert_eq!(res.initially_affected, 0);
    assert!(l1_distance(&res.ranks, &prev).unwrap() < 1e-12);
}

#[test]
fn run_approach_dispatch() {
    let Some(store) = store() else { return };
    let eng = DeviceEngine::new(&store);
    let cfg = PagerankConfig::default();
    let mut b = er::generate(400, 5.0, 9);
    let old_g = b.to_csr();
    let old_gt = old_g.transpose();
    let prev = native::static_pagerank(&old_g, &old_gt, &cfg, None).ranks;
    let upd = batch::random_batch(&b, 6, 0.8, 11);
    batch::apply(&mut b, &upd);
    let (g, gt, dg) = pack(&b, &store);
    let reference = native::static_pagerank(&g, &gt, &PagerankConfig::reference(), None).ranks;

    for a in Approach::ALL {
        let res = eng
            .run_approach(a, &dg, &g, &old_g, &cfg, Some(&prev), &upd)
            .unwrap();
        let err = l1_distance(&res.ranks, &reference).unwrap();
        assert!(err < 1e-3, "{a:?} err={err}");
    }
}

#[test]
fn kernel_artifacts_execute() {
    // standalone Pallas kernel artifacts: ell gather-sum + linf
    use pagerank_dynamic::runtime::artifacts::{lit_f64, lit_i32_2d, run, to_f64};
    let Some(store) = store() else { return };
    let tier = store.manifest().tier("t10").unwrap().clone();
    let exe = store.executable("kernel_ell_sum", "t10").unwrap();

    let mut contrib = vec![0.0f64; tier.v];
    for (i, c) in contrib.iter_mut().enumerate() {
        *c = (i % 13) as f64 * 0.25;
    }
    contrib[tier.v - 1] = 0.0; // sentinel
    let mut idx = vec![(tier.v - 1) as i32; tier.v * tier.w];
    // row 5 gathers slots 1, 2, 3
    for (k, slot) in [1, 2, 3].into_iter().enumerate() {
        idx[5 * tier.w + k] = slot;
    }
    let outs = run(
        &exe,
        &[&lit_f64(&contrib), &lit_i32_2d(&idx, tier.v, tier.w).unwrap()],
    )
    .unwrap();
    let sums = to_f64(&outs[0]).unwrap();
    assert_eq!(sums.len(), tier.v);
    assert!((sums[5] - (contrib[1] + contrib[2] + contrib[3])).abs() < 1e-12);
    assert_eq!(sums[0], 0.0);

    let exe = store.executable("kernel_linf", "t10").unwrap();
    let a = vec![0.5f64; tier.v];
    let mut b = vec![0.5f64; tier.v];
    b[77] = 0.125;
    let outs = run(&exe, &[&lit_f64(&a), &lit_f64(&b)]).unwrap();
    let linf = to_f64(&outs[0]).unwrap();
    assert_eq!(linf, vec![0.375]);
}

#[test]
fn warmup_compiles_tier() {
    let Some(store) = store() else { return };
    let n = store.warmup("t10").unwrap();
    assert!(n >= 14, "expected all t10 artifacts, got {n}");
}
