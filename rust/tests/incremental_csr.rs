//! Equivalence harness for incremental CSR maintenance (`graph::dyncsr`).
//!
//! Two contracts under test. Structurally: a [`DynCsr`] driven through
//! arbitrary clean batches stays *logically* identical to the legacy
//! rebuild path (`builder.to_csr()` + `transpose()`) — same rows, same
//! degrees (bitwise, through the f64 degree cache), same degree
//! partitions — across row relocations, arena compactions and
//! graph-emptying batches. Behaviorally: a coordinator in incremental CSR
//! mode serves ranks bitwise equal to one in rebuild mode, for every
//! approach of the paper, at every thread count and SIMD backend — the
//! slack layout (per-row headroom, non-monotone offsets) must be invisible
//! to every kernel. `ci.sh` additionally pins this cross-process: the
//! golden rank digests of `tests/pool_determinism.rs` are written under
//! both `PAGERANK_CSR` pins and diffed.

use pagerank_dynamic::batch::{self, BatchUpdate};
use pagerank_dynamic::coordinator::DynamicGraphService;
use pagerank_dynamic::engines::Approach;
use pagerank_dynamic::generators::{er, rmat};
use pagerank_dynamic::graph::{partition_by_degree, CsrMode, DynCsr, GraphBuilder};
use pagerank_dynamic::util::SimdPolicy;
use pagerank_dynamic::PagerankConfig;

/// Assert the incremental structure is logically identical to a from-scratch
/// rebuild of the same builder: rows, transpose, degree caches, partitions.
fn assert_tracks(dc: &DynCsr, b: &GraphBuilder, tag: &str) {
    let want_g = b.to_csr();
    let want_gt = want_g.transpose();
    let (g, gt) = dc.graphs();
    assert_eq!(g, &want_g, "{tag}: forward CSR diverged");
    assert_eq!(gt, &want_gt, "{tag}: transpose CSR diverged");
    assert_eq!(dc.num_edges(), b.num_edges(), "{tag}: edge count");
    for (side, got, want) in [("g", g, &want_g), ("gt", gt, &want_gt)] {
        let (a, c) = (got.degrees_f64(), want.degrees_f64());
        assert_eq!(a.len(), c.len(), "{tag}/{side}: degree length");
        for (i, (x, y)) in a.iter().zip(&c).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{tag}/{side}: deg_f64[{i}]");
        }
        // partitions (the paper's Algorithm 4) see identical degree vectors
        for threshold in [4u32, 1024] {
            let pa = partition_by_degree(&got.degrees(), threshold);
            let pb = partition_by_degree(&want.degrees(), threshold);
            assert_eq!(pa.low(), pb.low(), "{tag}/{side}: low partition");
            assert_eq!(pa.high(), pb.high(), "{tag}/{side}: high partition");
        }
    }
    // the packed snapshot is exactly the rebuild
    let (pg, pgt) = dc.to_packed();
    assert!(pg.is_packed() && pgt.is_packed(), "{tag}: to_packed layout");
    assert_eq!(pg, want_g, "{tag}: packed forward");
    assert_eq!(pgt, want_gt, "{tag}: packed transpose");
}

/// Seeded property test: random mixed batches through validation, applied
/// to builder and DynCsr in lockstep, must stay logically identical on
/// both ER and hub-heavy RMAT topologies.
#[test]
fn dyncsr_matches_rebuild_through_random_batches() {
    for (gname, mut b) in [
        ("er", er::generate(600, 5.0, 17)),
        ("rmat-web", rmat::generate(10, 6.0, rmat::RmatParams::WEB, 19)),
    ] {
        b.ensure_self_loops();
        let mut dc = DynCsr::from_builder(&b);
        assert_tracks(&dc, &b, &format!("{gname}/initial"));
        for seed in 0..10u64 {
            let raw = batch::random_batch(&b, 40, 0.6, 100 + seed);
            let clean = batch::validate(&b, &raw).clean;
            batch::apply(&mut b, &clean);
            dc.apply_batch(&clean);
            assert_tracks(&dc, &b, &format!("{gname}/seed{seed}"));
        }
    }
}

/// Deleting every real edge in one batch empties the adjacency (only
/// self-loops remain), overshoots the slack limit and forces a compaction;
/// a refill batch afterwards proves the compacted arena still grows.
#[test]
fn emptying_and_refilling_survives_compaction() {
    let mut b = er::generate(400, 16.0, 23);
    b.ensure_self_loops();
    let mut dc = DynCsr::from_builder(&b);
    let wipe = BatchUpdate { deletions: b.real_edges(), insertions: Vec::new() };
    let clean = batch::validate(&b, &wipe).clean;
    assert_eq!(clean.deletions.len(), wipe.deletions.len(), "wipe is all-clean");
    batch::apply(&mut b, &clean);
    dc.apply_batch(&clean);
    assert!(dc.compactions() > 0, "emptied arena must have compacted");
    assert_tracks(&dc, &b, "post-wipe");

    let refill = batch::random_batch(&b, 300, 1.0, 29);
    let clean = batch::validate(&b, &refill).clean;
    batch::apply(&mut b, &clean);
    dc.apply_batch(&clean);
    assert_tracks(&dc, &b, "post-refill");
}

/// Drive one seeded update sequence through two services that differ only
/// in CSR mode and assert bitwise-equal ranks after every update.
fn assert_modes_agree(cfg: PagerankConfig, forced: Option<Approach>, tag: &str) {
    let mk = |mode: CsrMode| {
        DynamicGraphService::new(er::generate(500, 5.0, 31), None, cfg.with_csr_mode(mode))
    };
    let mut inc = mk(CsrMode::Incremental);
    let mut reb = mk(CsrMode::Rebuild);
    inc.ensure_ranks().unwrap();
    reb.ensure_ranks().unwrap();
    // shadow builder: the services own theirs privately, so batches are
    // generated against a same-seed mirror kept in lockstep
    let mut shadow = er::generate(500, 5.0, 31);
    shadow.ensure_self_loops();
    for seed in 0..4u64 {
        let upd = batch::random_batch(&shadow, 10, 0.7, 7_000 + seed);
        batch::apply(&mut shadow, &upd);
        let (ri, rr) = match forced {
            Some(a) => (
                inc.apply_update_with(upd.clone(), a).unwrap(),
                reb.apply_update_with(upd, a).unwrap(),
            ),
            None => (inc.apply_update(upd.clone()).unwrap(), reb.apply_update(upd).unwrap()),
        };
        assert_eq!(ri.approach, rr.approach, "{tag}/seed{seed}: approach");
        assert_eq!(ri.iterations, rr.iterations, "{tag}/seed{seed}: iterations");
        assert_eq!(
            ri.initially_affected, rr.initially_affected,
            "{tag}/seed{seed}: affected"
        );
        assert_eq!(ri.num_edges, rr.num_edges, "{tag}/seed{seed}: edge count");
        for (i, (x, y)) in
            inc.ranks().unwrap().iter().zip(reb.ranks().unwrap()).enumerate()
        {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{tag}/seed{seed}: rank[{i}] diverged ({x} vs {y})"
            );
        }
    }
}

/// The service-level matrix: every approach of the paper × threads {1, 8}
/// × SIMD backend, incremental vs rebuild, bitwise.
#[test]
fn serving_ranks_bitwise_equal_across_modes_approaches_threads_simd() {
    let approaches = [
        None, // policy-chosen
        Some(Approach::NaiveDynamic),
        Some(Approach::DynamicTraversal),
        Some(Approach::DynamicFrontier),
        Some(Approach::DynamicFrontierPruning),
    ];
    for &threads in &[1usize, 8] {
        for simd in [SimdPolicy::Scalar, SimdPolicy::Vector] {
            let cfg =
                PagerankConfig::default().with_threads(threads).with_simd(simd);
            for forced in approaches {
                let tag = format!(
                    "t{threads}/{}/{}",
                    simd.as_str(),
                    forced.map_or("policy", |a| a.label())
                );
                assert_modes_agree(cfg, forced, &tag);
            }
        }
    }
}

/// A graph-emptying batch through the full service, both modes: the
/// post-wipe graph is self-loops only (uniform ranks), and both modes keep
/// serving identical bits through the wipe and a refill.
#[test]
fn serving_survives_graph_emptying_batch_in_both_modes() {
    let mk = |mode: CsrMode| {
        DynamicGraphService::new(
            er::generate(300, 12.0, 37),
            None,
            PagerankConfig::default().with_csr_mode(mode),
        )
    };
    let mut inc = mk(CsrMode::Incremental);
    let mut reb = mk(CsrMode::Rebuild);
    inc.ensure_ranks().unwrap();
    reb.ensure_ranks().unwrap();
    let mut shadow = er::generate(300, 12.0, 37);
    shadow.ensure_self_loops();

    let wipe = BatchUpdate { deletions: shadow.real_edges(), insertions: Vec::new() };
    batch::apply(&mut shadow, &wipe);
    let ri = inc.apply_update(wipe.clone()).unwrap();
    let rr = reb.apply_update(wipe).unwrap();
    assert_eq!(ri.num_edges, rr.num_edges);
    assert_eq!(ri.num_edges, shadow.num_edges(), "self-loops only");
    let n = shadow.num_vertices() as f64;
    for (i, (x, y)) in inc.ranks().unwrap().iter().zip(reb.ranks().unwrap()).enumerate()
    {
        assert_eq!(x.to_bits(), y.to_bits(), "wipe: rank[{i}]");
        assert!((x - 1.0 / n).abs() < 1e-8, "wipe: rank[{i}] = {x} not uniform");
    }

    let refill = batch::random_batch(&shadow, 200, 1.0, 41);
    batch::apply(&mut shadow, &refill);
    inc.apply_update(refill.clone()).unwrap();
    reb.apply_update(refill).unwrap();
    for (i, (x, y)) in inc.ranks().unwrap().iter().zip(reb.ranks().unwrap()).enumerate()
    {
        assert_eq!(x.to_bits(), y.to_bits(), "refill: rank[{i}]");
    }
}
