//! Robustness suite: deterministic fault injection against the coordinator
//! and its serving front-end.
//!
//! Every fault class the [`pagerank_dynamic::coordinator::FaultPlan`]
//! harness can produce is driven end-to-end here, and the suite asserts the
//! three service-level guarantees of the robustness layer:
//!
//! 1. every injected fault is *detected* (quarantine report, watchdog trip,
//!    or supervisor respawn — never silent corruption);
//! 2. the service *keeps answering* `top_k` / `ranks_of` during recovery;
//! 3. post-recovery ranks match a from-scratch static reference.
//!
//! Everything is seeded: a failure replays bit-for-bit.

use std::time::Duration;

use pagerank_dynamic::batch::{self, BatchUpdate, UpdateError};
use pagerank_dynamic::coordinator::server::{spawn_with, ServerConfig, ServerError};
use pagerank_dynamic::coordinator::{Checkpoint, DynamicGraphService, Fault, FaultPlan};
use pagerank_dynamic::engines::error::{l1_distance, reference_ranks};
use pagerank_dynamic::engines::Approach;
use pagerank_dynamic::generators::er;
use pagerank_dynamic::graph::GraphBuilder;
use pagerank_dynamic::util::par;
use pagerank_dynamic::PagerankConfig;

/// A warmed native-only service plus a shadow builder mirroring its graph.
fn warm_service(n: usize, seed: u64) -> (DynamicGraphService, GraphBuilder) {
    let base = er::generate(n, 5.0, seed);
    let mut shadow = base.clone();
    shadow.ensure_self_loops();
    let mut s = DynamicGraphService::new(base, None, PagerankConfig::default());
    s.apply_update(BatchUpdate::default()).unwrap();
    (s, shadow)
}

fn assert_ranks_match_reference(s: &DynamicGraphService, shadow: &GraphBuilder, tol: f64) {
    let g = shadow.to_csr();
    let gt = g.transpose();
    let truth = reference_ranks(&g, &gt);
    let err = l1_distance(s.ranks().unwrap(), &truth).unwrap();
    assert!(err < tol, "L1 vs static reference: {err}");
}

// ---------------------------------------------------------------- ingestion

#[test]
fn empty_batch_is_noop() {
    let (mut s, shadow) = warm_service(200, 1);
    let before = s.ranks().unwrap().to_vec();
    let m0 = s.num_edges();
    let rep = s.apply_update(BatchUpdate::default()).unwrap();
    assert_eq!(rep.edges_changed, 0);
    assert_eq!(rep.quarantined, 0);
    assert_eq!(s.num_edges(), m0);
    assert_ranks_match_reference(&s, &shadow, 1e-6);
    // an empty batch must not move the installed ranks materially
    let drift = l1_distance(s.ranks().unwrap(), &before).unwrap();
    assert!(drift < 1e-9, "empty batch moved ranks by {drift}");
}

#[test]
fn all_duplicate_insertions_are_quarantined() {
    let (mut s, shadow) = warm_service(200, 2);
    let m0 = s.num_edges();
    let dup: Vec<_> = shadow.real_edges().into_iter().take(5).collect();
    assert_eq!(dup.len(), 5);
    let rep = s
        .apply_update(BatchUpdate { deletions: vec![], insertions: dup })
        .unwrap();
    assert_eq!(rep.quarantined, 5);
    assert_eq!(rep.edges_changed, 0);
    assert_eq!(s.num_edges(), m0, "graph unchanged");
    assert!(rep
        .rejections
        .iter()
        .all(|r| r.error == UpdateError::DuplicateInsertion));
    assert_eq!(s.metrics.quarantined_edits, 5);
}

#[test]
fn phantom_deletions_are_quarantined() {
    let (mut s, shadow) = warm_service(150, 3);
    let n = shadow.num_vertices();
    // find an absent (non-self-loop) edge to "delete"
    let v = (1..n as u32).find(|&v| !shadow.has_edge(0, v)).unwrap();
    let rep = s
        .apply_update(BatchUpdate { deletions: vec![(0, v)], insertions: vec![] })
        .unwrap();
    assert_eq!(rep.quarantined, 1);
    assert_eq!(rep.rejections[0].error, UpdateError::PhantomDeletion);
    assert_eq!(rep.edges_changed, 0);
}

#[test]
fn boundary_vertex_id_is_out_of_range() {
    // id == num_vertices is the canonical off-by-one: must be quarantined,
    // not a builder panic
    let (mut s, _) = warm_service(100, 4);
    let n = s.num_vertices() as u32;
    let rep = s
        .apply_update(BatchUpdate {
            deletions: vec![(n, 0)],
            insertions: vec![(0, n), (n, n)],
        })
        .unwrap();
    assert_eq!(rep.quarantined, 3);
    assert!(rep
        .rejections
        .iter()
        .all(|r| matches!(r.error, UpdateError::OutOfRange { num_vertices } if num_vertices == 100)));
}

#[test]
fn insert_and_delete_same_edge_in_one_batch() {
    let (mut s, shadow) = warm_service(150, 5);
    let m0 = s.num_edges();
    // existing edge: delete-then-reinsert is legal (deletions apply first)
    let e = shadow.real_edges()[0];
    let rep = s
        .apply_update(BatchUpdate { deletions: vec![e], insertions: vec![e] })
        .unwrap();
    assert_eq!(rep.quarantined, 0);
    assert_eq!(rep.edges_changed, 2, "both edits executed");
    assert_eq!(s.num_edges(), m0, "net zero");
    // absent edge: the phantom deletion is quarantined, the insertion lands
    let n = shadow.num_vertices() as u32;
    let v = (1..n).find(|&v| !shadow.has_edge(0, v)).unwrap();
    let rep = s
        .apply_update(BatchUpdate { deletions: vec![(0, v)], insertions: vec![(0, v)] })
        .unwrap();
    assert_eq!(rep.quarantined, 1);
    assert_eq!(rep.rejections[0].error, UpdateError::PhantomDeletion);
    assert_eq!(rep.edges_changed, 1);
    assert_eq!(s.num_edges(), m0 + 1);
}

#[test]
fn malformed_batch_fault_is_fully_quarantined() {
    let (mut s, mut shadow) = warm_service(300, 6);
    s.arm_faults(FaultPlan::new(11).at(1, Fault::MalformedBatch { edits: 9 }));
    // a legitimate batch rides along with the injected garbage
    let good = batch::random_batch(&shadow, 4, 0.8, 41);
    batch::apply(&mut shadow, &good);
    let rep = s.apply_update(good).unwrap();
    assert_eq!(rep.quarantined, 9, "all injected edits rejected");
    assert_eq!(rep.edges_changed, 4, "the clean rider applied");
    assert_eq!(rep.watchdog_trips, 0);
    assert_eq!(s.num_edges(), shadow.num_edges());
    assert_ranks_match_reference(&s, &shadow, 1e-6);
}

// ----------------------------------------------------------------- watchdog

#[test]
fn nan_corruption_is_detected_and_recovered() {
    let (mut s, mut shadow) = warm_service(400, 7);
    s.arm_faults(FaultPlan::new(21).at(1, Fault::CorruptRanks { nans: 7 }));
    let b = batch::random_batch(&shadow, 3, 0.8, 51);
    batch::apply(&mut shadow, &b);
    let rep = s.apply_update(b).unwrap();
    assert_eq!(rep.watchdog_trips, 1, "corruption tripped exactly once");
    assert!(rep.degraded);
    assert!(s.degraded());
    assert_eq!(s.metrics.watchdog_trips, 1);
    assert_eq!(s.metrics.health_recoveries, 1);
    // the bad vector was never installed
    assert!(s.ranks().unwrap().iter().all(|r| r.is_finite()));
    assert_ranks_match_reference(&s, &shadow, 1e-6);
    // queries still answer while degraded
    assert_eq!(s.top_k(5).len(), 5);
}

#[test]
fn iteration_stall_is_detected_and_recovered() {
    let (mut s, mut shadow) = warm_service(400, 8);
    s.arm_faults(FaultPlan::new(22).at(1, Fault::Stall));
    let b = batch::random_batch(&shadow, 3, 0.8, 52);
    batch::apply(&mut shadow, &b);
    let rep = s.apply_update(b).unwrap();
    assert_eq!(rep.watchdog_trips, 1, "stall tripped the convergence check");
    assert!(rep.iterations < PagerankConfig::default().max_iterations);
    assert_ranks_match_reference(&s, &shadow, 1e-6);
}

#[test]
fn degraded_state_clears_on_static_refresh() {
    let (mut s, mut shadow) = warm_service(300, 9);
    s.arm_faults(FaultPlan::new(23).at(1, Fault::CorruptRanks { nans: 3 }));
    let b = batch::random_batch(&shadow, 2, 0.8, 53);
    batch::apply(&mut shadow, &b);
    s.apply_update(b).unwrap();
    assert!(s.degraded());
    // while degraded the policy stays conservative (ND, never DF-P)
    let b = batch::random_batch(&shadow, 1, 1.0, 54);
    batch::apply(&mut shadow, &b);
    let rep = s.apply_update(b).unwrap();
    assert_eq!(rep.approach, Approach::NaiveDynamic);
    // a successful full refresh restores healthy state
    let rep = s.refresh_static().unwrap();
    assert!(!rep.degraded);
    assert!(!s.degraded());
    assert_ranks_match_reference(&s, &shadow, 1e-6);
}

// ----------------------------------------------------- checkpoint / restore

#[test]
fn checkpoint_json_roundtrip_restores_bit_exact_ranks() {
    let (mut s, mut shadow) = warm_service(250, 10);
    let b = batch::random_batch(&shadow, 3, 0.8, 61);
    batch::apply(&mut shadow, &b);
    s.apply_update(b).unwrap();

    let cp = s.checkpoint();
    let doc = cp.to_json();
    let back = Checkpoint::from_json(&doc).unwrap();
    assert_eq!(back.seq, cp.seq);
    assert_eq!(back.edges, cp.edges);

    let r = DynamicGraphService::restore(&back, None).unwrap();
    assert_eq!(r.num_vertices(), s.num_vertices());
    assert_eq!(r.num_edges(), s.num_edges());
    assert_eq!(r.update_seq(), s.update_seq());
    assert_eq!(r.metrics.restores, 1);
    for (a, b) in r.ranks().unwrap().iter().zip(s.ranks().unwrap()) {
        assert_eq!(a.to_bits(), b.to_bits(), "ranks survive JSON bit-exact");
    }
    assert_ranks_match_reference(&r, &shadow, 1e-6);
}

#[test]
fn dt_stays_exact_across_checkpoint_restore() {
    // Dynamic Traversal BFS-marks reachability over old ∪ new graph, so it
    // is only exact if a restored service gets back the *true* previous
    // snapshot — which the checkpoint carries as a delta (prev_missing /
    // prev_extra), not a second edge list.
    //
    // 40-vertex chain 0→1→…→39. Cutting (20, 21) then inserting (5, 18)
    // makes the distinction observable: the old graph still bridges the
    // cut, so DT's exact affected set is {5..=39} (35 vertices). A restore
    // that substituted the current graph for the previous one would stop
    // at the cut (16 vertices) and converge to different bits.
    let mut b = GraphBuilder::new(40);
    for v in 0..39u32 {
        b.insert_edge(v, v + 1);
    }
    let mut s = DynamicGraphService::new(b, None, PagerankConfig::default());
    s.apply_update(BatchUpdate::default()).unwrap(); // seq 0: initial static
    let cut = BatchUpdate { deletions: vec![(20, 21)], insertions: vec![] };
    s.apply_update(cut).unwrap(); // seq 1: prev snapshot = uncut chain

    let cp = s.checkpoint();
    let mut restored = DynamicGraphService::restore(&cp, None).unwrap();

    let b2 = BatchUpdate { deletions: vec![], insertions: vec![(5, 18)] };
    let live = s
        .apply_update_with(b2.clone(), Approach::DynamicTraversal)
        .unwrap();
    let resto = restored
        .apply_update_with(b2, Approach::DynamicTraversal)
        .unwrap();
    assert_eq!(live.approach, Approach::DynamicTraversal);
    assert_eq!(
        live.initially_affected, 35,
        "BFS crosses the cut through the old graph"
    );
    assert_eq!(
        resto.initially_affected, live.initially_affected,
        "restored DT sees the same previous snapshot"
    );
    assert_eq!(resto.iterations, live.iterations);
    for (a, b) in restored.ranks().unwrap().iter().zip(s.ranks().unwrap()) {
        assert_eq!(a.to_bits(), b.to_bits(), "post-restore DT ranks bitwise equal");
    }
}

#[test]
fn restore_rejects_tampered_checkpoint() {
    let (s, _) = warm_service(100, 11);
    let mut cp = s.checkpoint();
    cp.ranks.as_mut().unwrap()[0] = f64::INFINITY;
    assert!(DynamicGraphService::restore(&cp, None).is_err());
    let mut cp = s.checkpoint();
    cp.edges.push((5_000, 0));
    assert!(DynamicGraphService::restore(&cp, None).is_err());
}

// ----------------------------------------------------------------- serving

#[test]
fn supervisor_respawns_after_kill_and_keeps_serving() {
    let n = 500usize;
    let base = er::generate(n, 5.0, 12);
    let mut shadow = base.clone();
    shadow.ensure_self_loops();
    let plan = FaultPlan::new(31).at(2, Fault::KillCoordinator);
    let h = spawn_with(
        move || {
            let mut s = DynamicGraphService::new(base, None, PagerankConfig::default());
            s.arm_faults(plan);
            s
        },
        ServerConfig { queue_capacity: 8, checkpoint_every: 1, respawn_limit: 2 },
    );

    h.update(BatchUpdate::default()).unwrap(); // seq 0: initial static
    let b1 = batch::random_batch(&shadow, 2, 0.8, 71);
    batch::apply(&mut shadow, &b1);
    h.update(b1).unwrap(); // seq 1 — checkpointed
    assert!(h.last_checkpoint().is_some());

    // seq 2: the injected panic. The in-flight request is dropped (typed,
    // retryable), its batch is NOT applied anywhere.
    let err = h.update(batch::random_batch(&shadow, 2, 0.8, 72)).unwrap_err();
    assert_eq!(err, ServerError::Dropped);

    // the service answers during/after recovery without a new factory call
    let top = h.top_k(5).unwrap();
    assert_eq!(top.len(), 5);
    assert!(top.iter().all(|(_, r)| r.is_finite()));
    assert_eq!(h.respawns(), 1);

    // post-recovery updates land on the restored (warm) state
    let b3 = batch::random_batch(&shadow, 2, 0.8, 73);
    batch::apply(&mut shadow, &b3);
    let rep = h.update(b3).unwrap();
    assert_ne!(rep.approach, Approach::Static, "respawned warm, not cold");

    let g = shadow.to_csr();
    let gt = g.transpose();
    let truth = reference_ranks(&g, &gt);
    let served = h.ranks_of((0..n as u32).collect()).unwrap();
    let err = l1_distance(&served, &truth).unwrap();
    assert!(err < 1e-6, "post-recovery L1 vs reference: {err}");

    let stats = h.stats().unwrap();
    assert!(stats.contains("restores=1"), "{stats}");
}

#[test]
fn pool_task_panic_is_typed_and_leaves_pool_usable() {
    // a panic inside a pool task must not deadlock the region or kill the
    // workers: the submitter gets a typed PoolPanic after all chunks finish
    let caught = std::panic::catch_unwind(|| {
        let mut buf = vec![0u8; 3 * par::DEFAULT_BLOCK];
        par::par_for(2, par::DEFAULT_BLOCK, &mut buf, |start, _| {
            if start == 0 {
                panic!("injected: chunk zero dies");
            }
        });
    })
    .unwrap_err();
    let p = caught.downcast_ref::<par::PoolPanic>().expect("typed PoolPanic payload");
    assert_eq!(p.chunks, 1);
    assert!(p.to_string().contains("1 chunk panicked"), "{p}");

    // the same pool serves the next region cleanly
    let mut buf = vec![0u8; 3 * par::DEFAULT_BLOCK];
    par::par_for(2, par::DEFAULT_BLOCK, &mut buf, |_, chunk| {
        for x in chunk.iter_mut() {
            *x = 1;
        }
    });
    assert!(buf.iter().all(|&x| x == 1));
}

#[test]
fn poisoned_pool_region_respawns_supervisor_and_recovers() {
    // Fault::PoisonPool submits a parallel region whose first chunk panics.
    // The coordinator thread dies on the typed PoolPanic; the supervisor
    // must respawn it from the last checkpoint, and — critically — the
    // persistent pool workers must have survived to serve the respawn.
    let n = 400usize;
    let base = er::generate(n, 5.0, 17);
    let mut shadow = base.clone();
    shadow.ensure_self_loops();
    let plan = FaultPlan::new(37).at(2, Fault::PoisonPool);
    let h = spawn_with(
        move || {
            let mut s = DynamicGraphService::new(base, None, PagerankConfig::default());
            s.arm_faults(plan);
            s
        },
        ServerConfig { queue_capacity: 8, checkpoint_every: 1, respawn_limit: 2 },
    );

    h.update(BatchUpdate::default()).unwrap(); // seq 0: initial static
    let b1 = batch::random_batch(&shadow, 2, 0.8, 81);
    batch::apply(&mut shadow, &b1);
    h.update(b1).unwrap(); // seq 1 — checkpointed

    // seq 2: the poisoned region. Typed drop, batch not applied anywhere.
    let err = h.update(batch::random_batch(&shadow, 2, 0.8, 82)).unwrap_err();
    assert_eq!(err, ServerError::Dropped);
    assert_eq!(h.respawns(), 1);

    // post-respawn updates run parallel regions on the surviving pool
    let b3 = batch::random_batch(&shadow, 2, 0.8, 83);
    batch::apply(&mut shadow, &b3);
    let rep = h.update(b3).unwrap();
    assert_ne!(rep.approach, Approach::Static, "respawned warm, not cold");

    let g = shadow.to_csr();
    let gt = g.transpose();
    let truth = reference_ranks(&g, &gt);
    let served = h.ranks_of((0..n as u32).collect()).unwrap();
    let err = l1_distance(&served, &truth).unwrap();
    assert!(err < 1e-6, "post-recovery L1 vs reference: {err}");
}

#[test]
fn backpressure_and_deadline_errors_are_typed() {
    // a factory that sleeps keeps the queue undrained: deterministic
    // backpressure without racing a real computation
    let h = spawn_with(
        move || {
            std::thread::sleep(Duration::from_millis(400));
            DynamicGraphService::new(er::generate(120, 4.0, 13), None, PagerankConfig::default())
        },
        ServerConfig { queue_capacity: 1, ..Default::default() },
    );
    // first deadline request occupies the single queue slot and times out
    let e1 = h
        .top_k_with_deadline(3, Duration::from_millis(10))
        .unwrap_err();
    assert_eq!(e1, ServerError::DeadlineExceeded);
    // the queue is full now: typed backpressure, not a hang
    let e2 = h
        .update_with_deadline(BatchUpdate::default(), Duration::from_millis(10))
        .unwrap_err();
    assert_eq!(e2, ServerError::Backpressure { capacity: 1 });
    assert_eq!(e2.to_string(), "request queue full (1 slots)");
    // once the coordinator is up, blocking requests drain normally
    let rep = h.update(BatchUpdate::default()).unwrap();
    assert!(rep.iterations > 0);
    assert_eq!(h.top_k(3).unwrap().len(), 3);
}

#[test]
fn expired_update_is_shed_without_executing() {
    let s_graph = er::generate(150, 4.0, 14);
    let h = spawn_with(
        move || DynamicGraphService::new(s_graph, None, PagerankConfig::default()),
        ServerConfig::default(),
    );
    h.update(BatchUpdate::default()).unwrap();
    let before = h.stats().unwrap();
    let err = h
        .update_with_deadline(BatchUpdate::default(), Duration::ZERO)
        .unwrap_err();
    assert_eq!(err, ServerError::DeadlineExceeded);
    // the shed update never ran: the counter did not advance
    let after = h.stats().unwrap();
    assert_eq!(before, after, "shed request must not execute");
}

#[test]
fn unwarmed_queries_never_panic() {
    // direct service: no ranks computed yet
    let s = DynamicGraphService::new(er::generate(60, 4.0, 15), None, PagerankConfig::default());
    assert!(s.top_k(10).is_empty());
    assert!(s.ranks().is_none());
    assert!(s.metrics.summary().contains("updates=0"));
    // through the server: reads answer (empty / zero), nothing hangs
    let h = spawn_with(
        || DynamicGraphService::new(er::generate(60, 4.0, 15), None, PagerankConfig::default()),
        ServerConfig::default(),
    );
    assert!(h.top_k(10).unwrap().is_empty());
    assert_eq!(h.ranks_of(vec![0, 1, 2]).unwrap(), vec![0.0, 0.0, 0.0]);
    assert!(h.stats().unwrap().contains("updates=0"));
}

#[test]
fn poisoned_config_is_sanitized_not_fatal() {
    let cfg = PagerankConfig {
        alpha: f64::NAN,
        tau: -1.0,
        max_iterations: 0,
        ..PagerankConfig::default()
    };
    let mut s = DynamicGraphService::new(er::generate(100, 4.0, 16), None, cfg);
    assert_eq!(s.cfg.alpha, 0.85, "clamped to the paper default");
    let rep = s.apply_update(BatchUpdate::default()).unwrap();
    assert!(rep.iterations > 0);
    assert!(s.ranks().unwrap().iter().all(|r| r.is_finite() && *r >= 0.0));
}
