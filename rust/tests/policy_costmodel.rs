//! Selection-policy and cost-model invariants.
//!
//! The coordinator's approach choice must be a *pure function* of the batch
//! shape (deterministic — two replicas looking at the same update pick the
//! same engine) and must degrade **monotonically**: as batches grow, the
//! chosen approach can only move toward less incremental reuse (DF-P → ND
//! → Static on `Approach::incrementality`), never back. The A100 cost model
//! backing EXPERIMENTS.md gets the matching monotonicity checks: modeled
//! time never decreases in vertices, edges, iterations, or affected work.

use std::time::Duration;

use pagerank_dynamic::coordinator::policy::{ApproachPolicy, HealthState, PolicyConfig};
use pagerank_dynamic::costmodel::{
    a100_time, frontier_iteration_bytes, full_iteration_bytes, model_frontier_run,
    model_full_run,
};
use pagerank_dynamic::engines::Approach;

#[test]
fn choice_is_deterministic_for_fixed_batch_shape() {
    // same shape in, same approach out — across calls and across replicas
    let shapes = [
        (0usize, 1_000_000usize, true),
        (1, 1_000_000, true),
        (50, 1_000_000, true),
        (10_000, 1_000_000, true),
        (10, 1_000, true),
        (10, 1_000_000, false),
        (0, 0, true), // empty graph: max(1) guard, no division by zero
    ];
    let a = ApproachPolicy::default();
    let b = ApproachPolicy::new(PolicyConfig::default());
    for &(len, edges, prev) in &shapes {
        let first = a.choose(len, edges, prev);
        for _ in 0..3 {
            assert_eq!(a.choose(len, edges, prev), first, "same policy, same shape");
        }
        assert_eq!(b.choose(len, edges, prev), first, "replica agrees");
    }
}

#[test]
fn selection_degrades_monotonically_with_batch_size() {
    // larger batches must never pick a MORE incremental approach: walking
    // batch_len up at fixed |E|, incrementality is non-increasing
    let p = ApproachPolicy::default();
    for num_edges in [1_000usize, 100_000, 10_000_000] {
        let mut last = u8::MAX;
        let mut batch_len = 0usize;
        while batch_len <= num_edges {
            let inc = p.choose(batch_len, num_edges, true).incrementality();
            assert!(
                inc <= last,
                "batch {batch_len}/{num_edges}: incrementality rose {last} -> {inc}"
            );
            last = inc;
            batch_len = batch_len * 2 + 1;
        }
    }
}

#[test]
fn monotonicity_survives_degraded_and_tripped_states() {
    // the degraded/tripped policies pin ND for every batch size — trivially
    // monotone, and never more incremental than the healthy choice
    let healthy = ApproachPolicy::default();
    let mut degraded = ApproachPolicy::default();
    degraded.escalate(Approach::DynamicFrontierPruning);
    assert_eq!(degraded.health(), HealthState::Degraded);
    let mut tripped = ApproachPolicy::default();
    tripped.observe_error(1.0);
    assert!(tripped.error_tripped());
    for p in [&degraded, &tripped] {
        let mut last = u8::MAX;
        for batch_len in [0usize, 1, 100, 10_000, 1_000_000] {
            let a = p.choose(batch_len, 1_000_000, true);
            assert_eq!(a, Approach::NaiveDynamic);
            let inc = a.incrementality();
            assert!(inc <= last);
            assert!(
                inc <= healthy.choose(batch_len, 1_000_000, true).incrementality(),
                "unhealthy policy must not out-reuse the healthy one"
            );
            last = inc;
        }
    }
}

#[test]
fn first_snapshot_always_recomputes() {
    // has_previous = false dominates everything, at every batch size
    let mut p = ApproachPolicy::default();
    assert_eq!(p.choose(0, 1_000, false), Approach::Static);
    p.observe_error(1.0);
    p.escalate(Approach::NaiveDynamic);
    assert_eq!(p.choose(1_000_000, 1_000, false), Approach::Static);
    assert_eq!(Approach::Static.incrementality(), 0, "static reuses nothing");
}

#[test]
fn incrementality_orders_the_ladder() {
    // the scale matches the degradation ladder: every escalation strictly
    // lowers incrementality until the ladder bottoms out at Static
    let mut seen = Vec::new();
    for a in Approach::ALL {
        seen.push(a.incrementality());
        let mut p = ApproachPolicy::default();
        if let Some(fallback) = p.escalate(a) {
            assert!(
                fallback.incrementality() < a.incrementality(),
                "{} -> {} must lose incrementality",
                a.label(),
                fallback.label()
            );
        } else {
            assert_eq!(a, Approach::Static, "only Static has no fallback");
        }
    }
    seen.sort_unstable();
    seen.dedup();
    assert_eq!(seen.len(), Approach::ALL.len(), "scale is a total order");
}

#[test]
fn modeled_time_monotone_in_problem_size() {
    // full-run model: non-decreasing in n, m and iterations
    let base = model_full_run(1_000_000, 16_000_000, 50);
    assert!(model_full_run(2_000_000, 16_000_000, 50) >= base);
    assert!(model_full_run(1_000_000, 32_000_000, 50) >= base);
    assert!(model_full_run(1_000_000, 16_000_000, 51) > base);
    assert_eq!(model_full_run(0, 0, 0), Duration::ZERO);

    // per-iteration byte counts: strictly increasing in each argument
    assert!(full_iteration_bytes(1_001, 500) > full_iteration_bytes(1_000, 500));
    assert!(full_iteration_bytes(1_000, 501) > full_iteration_bytes(1_000, 500));
    let f = frontier_iteration_bytes(1_000, 10, 100);
    assert!(frontier_iteration_bytes(1_001, 10, 100) > f);
    assert!(frontier_iteration_bytes(1_000, 11, 100) > f);
    assert!(frontier_iteration_bytes(1_000, 10, 101) > f);
}

#[test]
fn frontier_model_bounded_by_full_model() {
    // a frontier iteration touching the whole graph costs at least a full
    // iteration's edge traffic, and shrinking affected work can only help
    let n = 100_000usize;
    let m = 1_600_000u64;
    let all = model_frontier_run(n, (0..10).map(|_| (n, m)));
    let some = model_frontier_run(n, (0..10).map(|_| (n / 100, m / 100)));
    let none = model_frontier_run(n, (0..10).map(|_| (0usize, 0u64)));
    assert!(none < some && some < all, "monotone in affected work");
    let full = model_full_run(n, m as usize, 10);
    // frontier-touching-everything adds the flag scan on top of full work
    assert!(all >= full);
    assert!(a100_time(0.0, 0) == Duration::ZERO);
}
