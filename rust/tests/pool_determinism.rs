//! Bitwise-equivalence harness for the persistent work-stealing pool and
//! the SIMD backends.
//!
//! Two contracts under test. From `util::par`: work decomposition depends
//! only on input size, partial results land in chunk-indexed slots, and
//! reductions fold those slots in ascending order — so the *execution*
//! schedule (which lane ran which chunk, in what order, stolen or not) can
//! never leak into the f64 ranks. From `util::simd`: every vectorized
//! inner loop uses a fixed lane-tree reduction order shared by the scalar
//! and vector backends — so the *instruction path* can't leak either.
//! These tests pin both contracts three ways:
//!
//! 1. every engine × generator × thread count × execution mode (persistent
//!    pool vs legacy per-region scoped spawn) × SIMD backend (scalar vs
//!    vector) produces ranks bitwise equal to the single-threaded scalar
//!    run;
//! 2. a seeded stress hook injecting per-chunk delays — forcing steals and
//!    scrambling completion order — changes nothing, on either backend;
//! 3. a golden rank digest written per (resolved thread count, SIMD pin),
//!    diffed by `ci.sh` across all four `PAGERANK_THREADS` ∈ {1, 8} ×
//!    `PAGERANK_SIMD` ∈ {0, 1} combinations.

use std::fmt::Write as _;

use pagerank_dynamic::batch::{self, BatchUpdate};
use pagerank_dynamic::engines::native::dynamic::{dynamic_frontier, dynamic_traversal};
use pagerank_dynamic::engines::native::{naive_dynamic, static_pagerank};
use pagerank_dynamic::engines::PagerankResult;
use pagerank_dynamic::generators::{chain, er, grid, rmat};
use pagerank_dynamic::graph::GraphBuilder;
use pagerank_dynamic::util::{digest, par, SimdPolicy};
use pagerank_dynamic::{CsrGraph, PagerankConfig};

/// Thread counts covering inline (1), fewer lanes than workers, a prime
/// count that misaligns with chunk counts, and more lanes than most CI
/// machines have cores (16 → guaranteed starvation + stealing).
const THREADS: [usize; 5] = [1, 2, 3, 7, 16];

fn generators() -> Vec<(&'static str, GraphBuilder)> {
    vec![
        // long dependency chains: worst case for static lane balance
        ("chain", chain::generate(2_000, 40, 5)),
        // uniform degree: the easy case, catches plain indexing bugs
        ("grid", grid::generate(40, 50, 7)),
        // random degrees around the mean
        ("er", er::generate(2_500, 6.0, 11)),
        // skewed web-like RMAT: hubs + stragglers, the stealing showcase
        ("rmat-web", rmat::generate(12, 8.0, rmat::RmatParams::WEB, 13)),
    ]
}

struct Scenario {
    old_g: CsrGraph,
    g: CsrGraph,
    gt: CsrGraph,
    prev: Vec<f64>,
    upd: BatchUpdate,
}

/// Old graph → reference ranks → batch → new graph: everything the five
/// approaches need, with the previous ranks computed single-threaded so
/// every comparison starts from identical bits.
fn scenario(mut b: GraphBuilder) -> Scenario {
    b.ensure_self_loops();
    let old_g = b.to_csr();
    let old_gt = old_g.transpose();
    // single-threaded *scalar* reference: the base bits every matrix cell —
    // thread count, pool mode, SIMD backend — must reproduce exactly
    let cfg = PagerankConfig::default()
        .with_threads(1)
        .with_simd(SimdPolicy::Scalar);
    let prev = static_pagerank(&old_g, &old_gt, &cfg, None).ranks;
    let upd = batch::random_batch(&b, 20, 0.7, 123);
    batch::apply(&mut b, &upd);
    let g = b.to_csr();
    let gt = g.transpose();
    Scenario { old_g, g, gt, prev, upd }
}

/// Run all five approaches of the paper against one scenario.
fn run_all(sc: &Scenario, cfg: &PagerankConfig) -> Vec<(&'static str, PagerankResult)> {
    vec![
        ("static", static_pagerank(&sc.g, &sc.gt, cfg, None)),
        ("nd", naive_dynamic(&sc.g, &sc.gt, cfg, &sc.prev)),
        (
            "dt",
            dynamic_traversal(&sc.g, &sc.gt, &sc.old_g, cfg, &sc.prev, &sc.upd),
        ),
        ("df", dynamic_frontier(&sc.g, &sc.gt, cfg, &sc.prev, &sc.upd, false)),
        ("dfp", dynamic_frontier(&sc.g, &sc.gt, cfg, &sc.prev, &sc.upd, true)),
    ]
}

fn assert_bitwise(
    tag: &str,
    base: &[(&'static str, PagerankResult)],
    got: &[(&'static str, PagerankResult)],
) {
    for ((name, b), (_, g)) in base.iter().zip(got) {
        assert_eq!(b.iterations, g.iterations, "{tag}/{name}: iteration count");
        assert_eq!(
            b.initially_affected, g.initially_affected,
            "{tag}/{name}: initially-affected count"
        );
        assert_eq!(b.ranks.len(), g.ranks.len(), "{tag}/{name}: rank length");
        for (i, (x, y)) in b.ranks.iter().zip(&g.ranks).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{tag}/{name}: rank[{i}] diverged ({x} vs {y})"
            );
        }
    }
}

/// The full matrix: engines × generators × thread counts × execution modes
/// × SIMD backends, every cell bitwise equal to the single-threaded scalar
/// persistent-pool run.
#[test]
fn every_engine_is_bitwise_identical_across_threads_modes_and_backends() {
    for (gname, b) in generators() {
        let sc = scenario(b);
        let base = run_all(
            &sc,
            &PagerankConfig::default()
                .with_threads(1)
                .with_simd(SimdPolicy::Scalar),
        );
        for &t in &THREADS {
            for persistent in [true, false] {
                for simd in [SimdPolicy::Scalar, SimdPolicy::Vector] {
                    let cfg = PagerankConfig::default()
                        .with_threads(t)
                        .with_pool_persistent(persistent)
                        .with_simd(simd);
                    let mode = if persistent { "pool" } else { "spawn" };
                    let got = run_all(&sc, &cfg);
                    assert_bitwise(
                        &format!("{gname}/t{t}/{mode}/{}", simd.as_str()),
                        &base,
                        &got,
                    );
                }
            }
        }
    }
}

/// Seeded per-chunk delays scramble which lane finishes which chunk first,
/// forcing steals in the middle of every region — results must not move,
/// on either SIMD backend.
#[test]
fn forced_steals_under_stress_delays_change_nothing() {
    let sc = scenario(er::generate(30_000, 4.0, 21));
    let base = run_all(
        &sc,
        &PagerankConfig::default()
            .with_threads(1)
            .with_simd(SimdPolicy::Scalar),
    );
    for seed in [1u64, 2026] {
        for simd in [SimdPolicy::Scalar, SimdPolicy::Vector] {
            par::set_stress_delay(seed, 60);
            let got =
                run_all(&sc, &PagerankConfig::default().with_threads(7).with_simd(simd));
            par::set_stress_delay(0, 0);
            assert_bitwise(&format!("stress/seed{seed}/{}", simd.as_str()), &base, &got);
        }
    }
}

/// Write a digest of every engine's rank bits under the *resolved* thread
/// count, SIMD pin and CSR-mode pin (so `PAGERANK_THREADS`,
/// `PAGERANK_SIMD` and `PAGERANK_CSR` apply — the config stays `Auto`).
/// `ci.sh` runs the suite under {threads 1, 8} × {simd 0, 1} × {csr
/// rebuild, incremental} combinations and diffs the files: any schedule-,
/// thread-, instruction-path- or CSR-layout-dependent bit anywhere in the
/// engine or serving stack fails the gate. Hashing goes through
/// `util::digest::fnv1a_ranks`, which normalizes `-0.0` so a semantically
/// equal sign-of-zero bit can never fail the diff.
///
/// Two sections per file: the raw engine matrix (CSR-mode independent —
/// the engines see whatever CSR they are handed), then a serving section
/// driving a coordinator end-to-end, where `PAGERANK_CSR` decides between
/// incremental maintenance and per-update rebuild.
#[test]
fn write_golden_rank_digest() {
    use pagerank_dynamic::coordinator::DynamicGraphService;
    use pagerank_dynamic::graph::CsrMode;

    let resolved = par::resolve(0);
    let simd_pin = match std::env::var("PAGERANK_SIMD") {
        Ok(s) if s.trim() == "0" => 0,
        _ => 1,
    };
    // same resolution the coordinator applies to CsrMode::Auto
    let csr_pin = if CsrMode::default().resolve_incremental() { "i" } else { "r" };
    let mut out = String::new();
    for (gname, b) in generators() {
        let sc = scenario(b);
        for (ename, res) in run_all(&sc, &PagerankConfig::default()) {
            let h = digest::fnv1a_ranks(&res.ranks);
            let _ = writeln!(out, "{gname} {ename} {h:016x} iters={}", res.iterations);
        }
    }
    // serving section: coordinator end-to-end (validation, maintenance,
    // policy, engines) over a seeded update sequence
    for (gname, b) in generators() {
        let mut shadow = b.clone();
        shadow.ensure_self_loops();
        let mut svc = DynamicGraphService::new(b, None, PagerankConfig::default());
        svc.ensure_ranks().unwrap();
        for seed in 0..3u64 {
            let upd = batch::random_batch(&shadow, 8, 0.7, 9_000 + seed);
            batch::apply(&mut shadow, &upd);
            svc.apply_update(upd).unwrap();
            let h = digest::fnv1a_ranks(svc.ranks().unwrap());
            let _ = writeln!(out, "serve-{gname} seed{seed} {h:016x}");
        }
    }
    // cwd of integration tests is the crate root (rust/); the workspace
    // build dir lives at ../target, so rust/target is ours alone.
    std::fs::create_dir_all("target").unwrap();
    std::fs::write(
        format!("target/rank_digest_t{resolved}_s{simd_pin}_c{csr_pin}.txt"),
        out,
    )
    .unwrap();
}
