//! Property-based tests over the engine and substrate invariants
//! (hand-rolled generator loops — offline build, no proptest; each property
//! is checked across many seeded random instances and graph families).

use pagerank_dynamic::batch::{self, BatchUpdate};
use pagerank_dynamic::engines::error::{l1_distance, linf_distance};
use pagerank_dynamic::engines::native::affected::{
    dt_affected, expand_affected, initial_affected,
};
use pagerank_dynamic::engines::native;
use pagerank_dynamic::generators::{chain, er, grid, rmat};
use pagerank_dynamic::graph::{partition_by_degree, GraphBuilder};
use pagerank_dynamic::util::Rng;
use pagerank_dynamic::PagerankConfig;

fn random_builder(seed: u64) -> GraphBuilder {
    let mut rng = Rng::seed_from_u64(seed);
    match seed % 4 {
        0 => er::generate(50 + rng.gen_range(400), 2.0 + rng.gen_f64() * 6.0, seed),
        1 => rmat::generate(
            7 + (seed % 3) as u32,
            3.0 + rng.gen_f64() * 8.0,
            rmat::RmatParams::WEB,
            seed,
        ),
        2 => grid::generate(8 + rng.gen_range(20), 8 + rng.gen_range(20), seed),
        _ => chain::generate(100 + rng.gen_range(900), 20 + rng.gen_range(80), seed),
    }
}

/// Ranks are a probability distribution and respect τ against a
/// tighter-converged run.
#[test]
fn prop_static_ranks_are_distribution() {
    let cfg = PagerankConfig::default();
    for seed in 0..12u64 {
        let g = random_builder(seed).to_csr();
        let gt = g.transpose();
        let res = native::static_pagerank(&g, &gt, &cfg, None);
        let sum: f64 = res.ranks.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6, "seed {seed}: sum {sum}");
        assert!(res.ranks.iter().all(|&r| r > 0.0), "seed {seed}: positivity");
        let tight = native::static_pagerank(
            &g,
            &gt,
            &PagerankConfig { tau: 1e-13, ..cfg },
            None,
        );
        assert!(linf_distance(&res.ranks, &tight.ranks).unwrap() < 1e-8, "seed {seed}");
    }
}

/// partition(degrees) is a permutation split exactly at the threshold.
#[test]
fn prop_partition_is_threshold_permutation() {
    for seed in 0..20u64 {
        let mut rng = Rng::seed_from_u64(seed);
        let n = 1 + rng.gen_range(3000);
        let threshold = rng.gen_range(40) as u32;
        let degrees: Vec<u32> = (0..n).map(|_| rng.gen_range(60) as u32).collect();
        let p = partition_by_degree(&degrees, threshold);
        assert_eq!(p.ids.len(), n);
        let mut seen = vec![false; n];
        for (i, &v) in p.ids.iter().enumerate() {
            assert!(!seen[v as usize], "duplicate {v}");
            seen[v as usize] = true;
            let low = degrees[v as usize] <= threshold;
            assert_eq!(low, i < p.n_low, "vertex {v} on wrong side");
        }
    }
}

/// DF initial affected set == brute-force recomputation of Algorithm 5.
#[test]
fn prop_initial_affected_matches_bruteforce() {
    for seed in 0..15u64 {
        let mut b = random_builder(seed);
        let upd = batch::random_batch(&b, 1 + (seed as usize % 20), 0.7, seed + 99);
        batch::apply(&mut b, &upd);
        let g = b.to_csr();
        let n = g.num_vertices();

        let (mut dv, dn) = initial_affected(n, &upd);
        expand_affected(&mut dv, &dn, &g);

        let mut want = vec![0u8; n];
        for &(u, v) in &upd.deletions {
            want[v as usize] = 1;
            for &w in g.neighbors(u) {
                want[w as usize] = 1;
            }
        }
        for &(u, _) in &upd.insertions {
            for &w in g.neighbors(u) {
                want[w as usize] = 1;
            }
        }
        assert_eq!(dv, want, "seed {seed}");
    }
}

/// DT's affected set contains every vertex whose rank meaningfully changes
/// (the correctness argument behind Dynamic Traversal).
#[test]
fn prop_dt_affected_covers_rank_changes() {
    let cfg = PagerankConfig::default();
    for seed in 20..28u64 {
        let mut b = random_builder(seed);
        let old_g = b.to_csr();
        let old_gt = old_g.transpose();
        let before = native::static_pagerank(&old_g, &old_gt, &cfg, None).ranks;
        let upd = batch::random_batch(&b, 4, 0.8, seed * 3 + 1);
        batch::apply(&mut b, &upd);
        let g = b.to_csr();
        let gt = g.transpose();
        let after = native::static_pagerank(&g, &gt, &cfg, None).ranks;
        let aff = dt_affected(&g, &old_g, &upd);
        for v in 0..g.num_vertices() {
            let delta = (after[v] - before[v]).abs() / after[v].max(before[v]);
            if delta > 1e-4 && aff[v] == 0 {
                let is_del_target =
                    upd.deletions.iter().any(|&(_, t)| t as usize == v);
                assert!(
                    is_del_target,
                    "seed {seed}: vertex {v} changed {delta:.2e} but unmarked"
                );
            }
        }
    }
}

/// DF/DF-P converge to the true (from-scratch) ranks within the paper's
/// acceptability band across graph families.
#[test]
fn prop_frontier_error_bounded() {
    let cfg = PagerankConfig::default();
    for seed in 40..52u64 {
        let mut b = random_builder(seed);
        let g0 = b.to_csr();
        let gt0 = g0.transpose();
        let prev = native::static_pagerank(&g0, &gt0, &cfg, None).ranks;
        let upd = batch::random_batch(&b, 1 + (seed as usize % 10), 0.8, seed);
        batch::apply(&mut b, &upd);
        let g = b.to_csr();
        let gt = g.transpose();
        let truth =
            native::static_pagerank(&g, &gt, &PagerankConfig::reference(), None).ranks;
        for prune in [false, true] {
            let res =
                native::dynamic::dynamic_frontier(&g, &gt, &cfg, &prev, &upd, prune);
            let err = l1_distance(&res.ranks, &truth).unwrap();
            assert!(err < 1e-2, "seed {seed} prune={prune}: err {err}");
        }
    }
}

/// Applying a batch then its inverse restores the original edge multiset.
#[test]
fn prop_batch_apply_revert() {
    for seed in 60..75u64 {
        let mut b = random_builder(seed);
        b.ensure_self_loops();
        let mut edges_before: Vec<_> = b.real_edges();
        edges_before.sort_unstable();
        let upd = batch::random_batch(&b, 10, 0.5, seed);
        batch::apply(&mut b, &upd);
        let inv = BatchUpdate {
            deletions: upd.insertions.clone(),
            insertions: upd.deletions.clone(),
        };
        batch::apply(&mut b, &inv);
        let mut edges_after: Vec<_> = b.real_edges();
        edges_after.sort_unstable();
        assert_eq!(edges_before, edges_after, "seed {seed}");
    }
}

/// CSR transpose is an involution on the edge multiset.
#[test]
fn prop_transpose_involution() {
    for seed in 80..95u64 {
        let g = random_builder(seed).to_csr();
        let gtt = g.transpose().transpose();
        assert_eq!(g.num_edges(), gtt.num_edges());
        for v in 0..g.num_vertices() as u32 {
            let mut a = g.neighbors(v).to_vec();
            let mut b = gtt.neighbors(v).to_vec();
            a.sort_unstable();
            b.sort_unstable();
            assert_eq!(a, b, "seed {seed} vertex {v}");
        }
    }
}

/// Empty update batches leave ranks untouched for every dynamic approach.
#[test]
fn prop_empty_batch_fixed_point() {
    let cfg = PagerankConfig::default();
    for seed in 100..106u64 {
        let b = random_builder(seed);
        let g = b.to_csr();
        let gt = g.transpose();
        let prev = native::static_pagerank(&g, &gt, &cfg, None).ranks;
        let empty = BatchUpdate::default();

        let df = native::dynamic::dynamic_frontier(&g, &gt, &cfg, &prev, &empty, false);
        assert_eq!(l1_distance(&df.ranks, &prev).unwrap(), 0.0, "DF seed {seed}");
        let dfp = native::dynamic::dynamic_frontier(&g, &gt, &cfg, &prev, &empty, true);
        assert_eq!(l1_distance(&dfp.ranks, &prev).unwrap(), 0.0, "DF-P seed {seed}");
        let dt = native::dynamic::dynamic_traversal(&g, &gt, &g, &cfg, &prev, &empty);
        assert_eq!(l1_distance(&dt.ranks, &prev).unwrap(), 0.0, "DT seed {seed}");
    }
}

/// In-degree hubs rank near the top on web-like graphs.
#[test]
fn prop_hub_dominance_on_weblike() {
    let cfg = PagerankConfig::default();
    let g = rmat::generate(10, 10.0, rmat::RmatParams::WEB, 7).to_csr();
    let gt = g.transpose();
    let ranks = native::static_pagerank(&g, &gt, &cfg, None).ranks;
    let (hub, _) = (0..g.num_vertices() as u32)
        .map(|v| (v, gt.degree(v)))
        .max_by_key(|&(_, d)| d)
        .unwrap();
    let hub_rank = ranks[hub as usize];
    let better = ranks.iter().filter(|&&r| r > hub_rank).count();
    assert!(better < g.num_vertices() / 50, "hub beaten by {better}");
}
