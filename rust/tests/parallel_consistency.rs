//! Parallel == sequential for every native approach (the tentpole
//! guarantee of the scoped-thread pool): ranks within 1e-12 L1 — in fact
//! bit-identical, since the work decomposition is thread-count invariant —
//! and identical iteration counts, across ER and RMAT (web-family, hubby)
//! graphs and threads ∈ {1, 2, 4, 8}. Plus regression coverage for the
//! OR-merged frontier expansion and the parallel graph builders.

use pagerank_dynamic::batch;
use pagerank_dynamic::engines::error::l1_distance;
use pagerank_dynamic::engines::native::affected::{expand_affected, expand_affected_threads};
use pagerank_dynamic::engines::native::dynamic::{dynamic_frontier, dynamic_traversal};
use pagerank_dynamic::engines::native::{naive_dynamic, static_pagerank};
use pagerank_dynamic::generators::{er, rmat};
use pagerank_dynamic::graph::partition::partition_by_degree_threads;
use pagerank_dynamic::graph::{CsrGraph, GraphBuilder};
use pagerank_dynamic::PagerankConfig;

const THREADS: [usize; 4] = [1, 2, 4, 8];

fn test_graphs() -> Vec<GraphBuilder> {
    vec![
        er::generate(3_000, 6.0, 11),
        rmat::generate(12, 8.0, rmat::RmatParams::WEB, 7), // skewed: hub path
    ]
}

fn assert_same_ranks(tag: &str, base: &pagerank_dynamic::engines::PagerankResult,
                     got: &pagerank_dynamic::engines::PagerankResult) {
    assert_eq!(got.iterations, base.iterations, "{tag}: iteration count drifted");
    assert!(
        l1_distance(&got.ranks, &base.ranks).unwrap() <= 1e-12,
        "{tag}: ranks drifted by {}",
        l1_distance(&got.ranks, &base.ranks).unwrap()
    );
    for (i, (a, b)) in got.ranks.iter().zip(&base.ranks).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{tag}: rank {i} not bit-identical");
    }
}

#[test]
fn static_parallel_matches_sequential() {
    for (gi, b) in test_graphs().into_iter().enumerate() {
        let g = b.to_csr();
        let gt = g.transpose();
        let base = static_pagerank(&g, &gt, &PagerankConfig::default().with_threads(1), None);
        for t in THREADS {
            let res = static_pagerank(&g, &gt, &PagerankConfig::default().with_threads(t), None);
            assert_same_ranks(&format!("static g{gi} t={t}"), &base, &res);
        }
    }
}

#[test]
fn naive_dynamic_parallel_matches_sequential() {
    for (gi, mut b) in test_graphs().into_iter().enumerate() {
        let cfg1 = PagerankConfig::default().with_threads(1);
        let prev = {
            let g = b.to_csr();
            let gt = g.transpose();
            static_pagerank(&g, &gt, &cfg1, None).ranks
        };
        let upd = batch::random_batch(&b, 25, 0.8, 77);
        batch::apply(&mut b, &upd);
        let g = b.to_csr();
        let gt = g.transpose();
        let base = naive_dynamic(&g, &gt, &cfg1, &prev);
        for t in THREADS {
            let cfg = PagerankConfig::default().with_threads(t);
            let res = naive_dynamic(&g, &gt, &cfg, &prev);
            assert_same_ranks(&format!("ND g{gi} t={t}"), &base, &res);
        }
    }
}

#[test]
fn frontier_approaches_parallel_match_sequential() {
    for (gi, mut b) in test_graphs().into_iter().enumerate() {
        let cfg1 = PagerankConfig::default().with_threads(1);
        let old_g = b.to_csr();
        let prev = {
            let gt = old_g.transpose();
            static_pagerank(&old_g, &gt, &cfg1, None).ranks
        };
        let upd = batch::random_batch(&b, 25, 0.8, 99);
        batch::apply(&mut b, &upd);
        let g = b.to_csr();
        let gt = g.transpose();

        for prune in [false, true] {
            let base = dynamic_frontier(&g, &gt, &cfg1, &prev, &upd, prune);
            for t in THREADS {
                let cfg = PagerankConfig::default().with_threads(t);
                let res = dynamic_frontier(&g, &gt, &cfg, &prev, &upd, prune);
                assert_eq!(
                    res.initially_affected, base.initially_affected,
                    "DF prune={prune} g{gi} t={t}: affected set drifted"
                );
                assert_same_ranks(&format!("DF prune={prune} g{gi} t={t}"), &base, &res);
            }
        }

        let base = dynamic_traversal(&g, &gt, &old_g, &cfg1, &prev, &upd);
        for t in THREADS {
            let cfg = PagerankConfig::default().with_threads(t);
            let res = dynamic_traversal(&g, &gt, &old_g, &cfg, &prev, &upd);
            assert_same_ranks(&format!("DT g{gi} t={t}"), &base, &res);
        }
    }
}

#[test]
fn expansion_or_merge_race_regression() {
    // Dense frontier pushing through high out-degree hubs: a shared-buffer
    // expansion races exactly here (many threads pushing a hub's out-edges
    // plus neighboring rows in the same edge blocks) and drops flags
    // intermittently. The per-thread-buffer OR-merge must match the
    // sequential push exactly, every time, at every width.
    let b = rmat::generate(13, 10.0, rmat::RmatParams::WEB, 3);
    let g = b.to_csr();
    let n = g.num_vertices();
    for trial in 0..5u64 {
        let mut dn = vec![0u8; n];
        // frontier = every 3rd vertex, phase-shifted per trial
        for v in ((trial as usize) % 3..n).step_by(3) {
            dn[v] = 1;
        }
        let mut want = vec![0u8; n];
        expand_affected(&mut want, &dn, &g);
        for t in [2, 4, 8] {
            let mut got = vec![0u8; n];
            expand_affected_threads(&mut got, &dn, &g, t);
            assert_eq!(got, want, "trial={trial} threads={t}");
        }
    }
}

#[test]
fn graph_builds_parallel_match_sequential() {
    let b = rmat::generate(12, 8.0, rmat::RmatParams::WEB, 21);
    let edges: Vec<(u32, u32)> = b.to_csr().edges().collect();
    let n = b.to_csr().num_vertices();
    let base = CsrGraph::from_edges_threads(n, &edges, 1);
    let base_t = base.transpose_threads(1);
    for t in THREADS {
        let g = CsrGraph::from_edges_threads(n, &edges, t);
        assert_eq!(g, base, "from_edges threads={t}");
        assert_eq!(g.transpose_threads(t), base_t, "transpose threads={t}");
    }
}

#[test]
fn degree_partition_parallel_matches_sequential() {
    let b = rmat::generate(13, 8.0, rmat::RmatParams::WEB, 5);
    let degrees = b.to_csr().degrees();
    for threshold in [4, 32, 1024] {
        let base = partition_by_degree_threads(&degrees, threshold, 1);
        for t in THREADS {
            let p = partition_by_degree_threads(&degrees, threshold, t);
            assert_eq!(p, base, "threshold={threshold} threads={t}");
        }
    }
}
