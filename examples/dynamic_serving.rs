//! End-to-end serving driver (the repo's headline example): a dynamic-graph
//! PageRank service under a live workload.
//!
//! A social-network-style graph receives a stream of batch updates while
//! concurrent reader threads issue top-k / rank queries; the coordinator
//! keeps ranks fresh with the policy-chosen approach (DF-P for small
//! batches, ND for large, Static for the first snapshot), executing on the
//! AOT-compiled PJRT artifacts. Reports per-batch latency, update
//! throughput, and final accuracy against a from-scratch reference run.
//!
//! Run with: `cargo run --release --example dynamic_serving`

use std::time::{Duration, Instant};

use anyhow::Result;

use pagerank_dynamic::batch::{self, random_batch, BatchUpdate};
use pagerank_dynamic::coordinator::server::spawn;
use pagerank_dynamic::coordinator::DynamicGraphService;
use pagerank_dynamic::engines::error::{l1_distance, reference_ranks};
use pagerank_dynamic::generators::rmat;
use pagerank_dynamic::runtime::ArtifactStore;
use pagerank_dynamic::PagerankConfig;

const NUM_BATCHES: usize = 30;
const BATCH_EDGES: usize = 8;

fn main() -> Result<()> {
    // a com-LiveJournal-style graph (power-law, ~16k vertices)
    let base = rmat::generate(14, 8.0, rmat::RmatParams::SOCIAL, 42);
    let n = base.num_vertices();
    let m = base.num_edges();
    println!("serving a social graph: n={n} m={m}");

    // shadow copy to generate valid updates + final reference
    let mut shadow = base.clone();

    // coordinator thread owns graph + PJRT store
    let handle = spawn(move || {
        let store = ArtifactStore::open_default().ok().map(std::sync::Arc::new);
        if store.is_none() {
            eprintln!("(artifacts missing: native fallback)");
        }
        let mut svc = DynamicGraphService::new(base, store, PagerankConfig::default());
        svc.policy.config.nd_batch_fraction = 1e-3; // small demo graph
        svc
    });

    // initial static computation
    let t0 = Instant::now();
    let first = handle.update(BatchUpdate::default())?;
    println!(
        "initial Static ranks: {} iterations, {:?} ({})\n",
        first.iterations,
        first.elapsed,
        if first.on_device { "device" } else { "native" }
    );

    // concurrent readers: hammer top-k / point queries while updates flow
    let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let mut readers = Vec::new();
    for r in 0..2 {
        let h = handle.clone();
        let stop = stop.clone();
        readers.push(std::thread::spawn(move || {
            let mut queries = 0usize;
            while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                if r == 0 {
                    let _ = h.top_k(10);
                } else {
                    let _ = h.ranks_of(vec![1, 2, 3, 4, 5]);
                }
                queries += 1;
                std::thread::sleep(Duration::from_millis(2));
            }
            queries
        }));
    }

    // the update stream
    let mut latencies = Vec::with_capacity(NUM_BATCHES);
    for i in 0..NUM_BATCHES {
        let upd = random_batch(&shadow, BATCH_EDGES, 0.8, 7_000 + i as u64);
        batch::apply(&mut shadow, &upd);
        let t = Instant::now();
        let rep = handle.update(upd)?;
        let lat = t.elapsed();
        latencies.push(lat.as_secs_f64());
        if i % 5 == 0 {
            println!(
                "batch {i:>3}: {} via {:5} — {:>2} iters, affected {:>5}, latency {:?}",
                rep.edges_changed,
                rep.approach.label(),
                rep.iterations,
                rep.initially_affected,
                lat
            );
        }
    }
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let total_queries: usize = readers.into_iter().map(|t| t.join().unwrap()).sum();

    // latency profile
    latencies.sort_by(f64::total_cmp);
    let pct = |p: f64| latencies[(p * (latencies.len() - 1) as f64) as usize];
    let wall = t0.elapsed().as_secs_f64();
    println!("\n--- serving report ---");
    println!("updates: {NUM_BATCHES} batches x {BATCH_EDGES} edges");
    println!(
        "update latency: p50 {:.1}ms  p90 {:.1}ms  p99 {:.1}ms",
        pct(0.50) * 1e3,
        pct(0.90) * 1e3,
        pct(0.99) * 1e3
    );
    println!(
        "throughput: {:.1} updates/s ({:.0} edge-changes/s) | {total_queries} reads served",
        NUM_BATCHES as f64 / wall,
        (NUM_BATCHES * BATCH_EDGES) as f64 / wall,
    );
    println!("{}", handle.stats()?);

    // final accuracy vs a from-scratch reference on the evolved graph
    let g = shadow.to_csr();
    let gt = g.transpose();
    let truth = reference_ranks(&g, &gt);
    let served: Vec<f64> = handle.ranks_of((0..n as u32).collect())?;
    let err = l1_distance(&served, &truth)?;
    println!("final L1 error vs from-scratch reference: {err:.3e}");
    assert!(err < 1e-2, "served ranks drifted: {err}");
    println!("dynamic_serving OK");
    Ok(())
}
