//! Temporal replay: the paper's real-world-dynamic-graph protocol on one
//! stream — load 90% of a temporal network, then replay the rest in batches,
//! updating ranks with all five approaches side by side (runtime + error per
//! batch, like Figures 9-13).
//!
//! Run with: `cargo run --release --example temporal_replay [stream-name]`

use std::collections::HashMap;

use anyhow::Result;

use pagerank_dynamic::batch;
use pagerank_dynamic::engines::error::l1_distance;
use pagerank_dynamic::engines::{native, Approach};
use pagerank_dynamic::harness::experiments::{Runner, Substrate};
use pagerank_dynamic::harness::fmt_dur;
use pagerank_dynamic::runtime::ArtifactStore;
use pagerank_dynamic::temporal;
use pagerank_dynamic::PagerankConfig;

fn main() -> Result<()> {
    let which = std::env::args().nth(1).unwrap_or_else(|| "sx-askubuntu".into());
    let tg = temporal::table3_standins()
        .into_iter()
        .find(|t| t.name == which)
        .unwrap_or_else(|| panic!("unknown stream {which}"));

    let bsize = ((tg.num_temporal_edges() as f64 * 1e-3) as usize).max(1);
    let (base, batches) = tg.replay(bsize, 12);
    println!(
        "{}: n={} |E_T|={} | replaying {} batches of {} edges\n",
        tg.name,
        tg.num_vertices,
        tg.num_temporal_edges(),
        batches.len(),
        bsize
    );

    let store = ArtifactStore::open_default().ok().map(std::sync::Arc::new);
    let substrate = if store.is_some() { Substrate::Device } else { Substrate::Native };
    let runner = Runner { store, cfg: PagerankConfig::default() };

    // per-approach rank state, as in the paper's measurement protocol
    let g0 = base.to_csr();
    let gt0 = g0.transpose();
    let init = native::static_pagerank(&g0, &gt0, &runner.cfg, None).ranks;
    let mut state: HashMap<Approach, Vec<f64>> =
        Approach::ALL.iter().map(|&a| (a, init.clone())).collect();

    println!(
        "{:>5}  {:>9} {:>9} {:>9} {:>9} {:>9}   {:>9} {:>8}",
        "batch", "Static", "ND", "DT", "DF", "DF-P", "err DF-P", "speedup"
    );
    let mut b = base.clone();
    for (i, upd) in batches.iter().enumerate() {
        let old = b.to_csr();
        batch::apply(&mut b, upd);
        let g = b.to_csr();
        let gt = g.transpose();
        let reference = native::static_pagerank(
            &g,
            &gt,
            &PagerankConfig { tau: 1e-14, ..runner.cfg },
            None,
        )
        .ranks;

        let mut times = HashMap::new();
        let mut err_dfp = 0.0;
        for &a in &Approach::ALL {
            let prev = state[&a].clone();
            let res = runner.run(a, substrate, &g, &gt, &old, Some(&prev), upd)?;
            times.insert(a, res.elapsed);
            if a == Approach::DynamicFrontierPruning {
                err_dfp = l1_distance(&res.ranks, &reference)?;
            }
            state.insert(a, res.ranks);
        }
        println!(
            "{:>5}  {:>9} {:>9} {:>9} {:>9} {:>9}   {:>9.1e} {:>7.1}x",
            i + 1,
            fmt_dur(times[&Approach::Static]),
            fmt_dur(times[&Approach::NaiveDynamic]),
            fmt_dur(times[&Approach::DynamicTraversal]),
            fmt_dur(times[&Approach::DynamicFrontier]),
            fmt_dur(times[&Approach::DynamicFrontierPruning]),
            err_dfp,
            times[&Approach::Static].as_secs_f64()
                / times[&Approach::DynamicFrontierPruning].as_secs_f64()
        );
    }
    println!("\ntemporal_replay OK ({:?} substrate)", substrate);
    Ok(())
}
