//! Approach explorer: dissects DF-P on one dataset — partition-mode
//! ablation (the paper's Figure 1), worklist compaction on/off, and the
//! frontier dynamics over iterations (how many vertices stay affected).
//!
//! Run with: `cargo run --release --example approach_explorer [dataset]`

use anyhow::Result;

use pagerank_dynamic::batch::{self, random_batch};
use pagerank_dynamic::engines::device::{DeviceEngine, PartitionMode};
use pagerank_dynamic::engines::native;
use pagerank_dynamic::generators::families;
use pagerank_dynamic::harness::fmt_dur;
use pagerank_dynamic::runtime::{ArtifactStore, DeviceGraph};
use pagerank_dynamic::PagerankConfig;

fn main() -> Result<()> {
    let which = std::env::args().nth(1).unwrap_or_else(|| "com-LiveJournal".into());
    let dataset = families::dataset(&which).unwrap_or_else(|| panic!("unknown dataset {which}"));

    let mut b = dataset.build();
    let g0 = b.to_csr();
    let gt0 = g0.transpose();
    let cfg = PagerankConfig::default();
    println!("{which}: n={} m={}", g0.num_vertices(), g0.num_edges());

    let prev = native::static_pagerank(&g0, &gt0, &cfg, None).ranks;
    let upd = random_batch(&b, (g0.num_edges() / 50_000).max(4), 0.8, 99);
    println!(
        "batch: {} insertions, {} deletions\n",
        upd.insertions.len(),
        upd.deletions.len()
    );
    batch::apply(&mut b, &upd);
    let g = b.to_csr();
    let gt = g.transpose();

    let store = ArtifactStore::open_default()?;
    let tier = store.tier_for(g.num_vertices(), g.num_edges()).unwrap();
    let dg = DeviceGraph::pack(&g, &gt, &tier)?;
    let eng = DeviceEngine::new(&store);

    println!("--- Figure-1 ablation: work partitioning (DF / DF-P) ---");
    println!("{:<26} {:>10} {:>10} {:>6}", "mode", "DF", "DF-P", "iters");
    let mut best = f64::MAX;
    let mut rows = Vec::new();
    for mode in [
        PartitionMode::DontPartition,
        PartitionMode::PartitionGPrime,
        PartitionMode::PartitionBoth,
        PartitionMode::PartitionBothPull,
    ] {
        let df = eng.dynamic_frontier(&dg, &g, &cfg, &prev, &upd, false, mode, false)?;
        let dfp = eng.dynamic_frontier(&dg, &g, &cfg, &prev, &upd, true, mode, false)?;
        best = best.min(dfp.elapsed.as_secs_f64());
        rows.push((mode, df.elapsed, dfp.elapsed, dfp.iterations));
    }
    for (mode, df, dfp, iters) in rows {
        println!(
            "{:<26} {:>10} {:>10} {:>6}   (DF-P rel {:.2})",
            mode.label(),
            fmt_dur(df),
            fmt_dur(dfp),
            iters,
            dfp.as_secs_f64() / best
        );
    }

    println!("\n--- worklist compaction (fixed-shape frontier skipping) ---");
    for (label, wl) in [("full-shape steps", false), ("worklist-compacted", true)] {
        let res = eng.dynamic_frontier(
            &dg,
            &g,
            &cfg,
            &prev,
            &upd,
            true,
            PartitionMode::PartitionBothPull,
            wl,
        )?;
        println!(
            "{label:<22} {:>10}  ({} iters, initially affected {})",
            fmt_dur(res.elapsed),
            res.iterations,
            res.initially_affected
        );
    }

    println!("\n--- native frontier dynamics (affected set per iteration) ---");
    // re-run the native DF-P step loop manually to expose the frontier size
    {
        use pagerank_dynamic::engines::native::affected::{
            expand_affected, initial_affected,
        };
        let n = g.num_vertices();
        let (mut dv, mut dn) = initial_affected(n, &upd);
        expand_affected(&mut dv, &dn, &g);
        let mut r = prev.clone();
        let mut r_new = prev.clone();
        let c0 = (1.0 - cfg.alpha) / n as f64;
        for it in 0..12 {
            let affected = dv.iter().filter(|&&x| x != 0).count();
            let mut contrib = vec![0.0; n];
            for (u, c) in contrib.iter_mut().enumerate() {
                *c = r[u] / g.degree(u as u32) as f64;
            }
            dn.iter_mut().for_each(|x| *x = 0);
            let mut linf = 0.0f64;
            for v in 0..n {
                if dv[v] == 0 {
                    r_new[v] = r[v];
                    continue;
                }
                let c: f64 = gt.neighbors(v as u32).iter().map(|&u| contrib[u as usize]).sum();
                let d_v = g.degree(v as u32) as f64;
                let nr = (cfg.alpha * (c - r[v] / d_v) + c0) / (1.0 - cfg.alpha / d_v);
                let rel = (nr - r[v]).abs() / nr.max(r[v]).max(1e-300);
                if rel <= cfg.tau_prune {
                    dv[v] = 0;
                }
                if rel > cfg.tau_frontier {
                    dn[v] = 1;
                }
                linf = linf.max((nr - r[v]).abs());
                r_new[v] = nr;
            }
            std::mem::swap(&mut r, &mut r_new);
            println!("iter {it:>2}: affected {affected:>7}  linf {linf:.2e}");
            if linf <= cfg.tau {
                break;
            }
            expand_affected(&mut dv, &dn, &g);
        }
    }

    println!("\napproach_explorer OK");
    Ok(())
}
