//! Quickstart: compute Static PageRank on a synthetic web-crawl stand-in,
//! on both the device (AOT artifacts via PJRT) and the native CPU engine,
//! and verify they agree.
//!
//! Run with: `cargo run --release --example quickstart`
//! (requires `make artifacts` once beforehand).

use anyhow::Result;

use pagerank_dynamic::engines::device::DeviceEngine;
use pagerank_dynamic::engines::error::l1_distance;
use pagerank_dynamic::engines::native;
use pagerank_dynamic::generators::families;
use pagerank_dynamic::runtime::{ArtifactStore, DeviceGraph};
use pagerank_dynamic::PagerankConfig;

fn main() -> Result<()> {
    // 1. build a graph (stand-in for the paper's it-2004 web crawl)
    let dataset = families::dataset("it-2004").unwrap();
    let g = dataset.build().to_csr();
    let gt = g.transpose();
    println!(
        "graph: {} vertices, {} edges (self-loops included)",
        g.num_vertices(),
        g.num_edges()
    );

    let cfg = PagerankConfig::default(); // α=0.85, τ=1e-10, 500 iters max

    // 2. "GPU" (PJRT device) run: pick a tier, pack, execute
    let store = ArtifactStore::open_default()?;
    let tier = store
        .tier_for(g.num_vertices(), g.num_edges())
        .expect("graph fits the compiled tiers");
    println!("device tier: {} (V={}, ECAP={})", tier.name, tier.v, tier.ecap);
    let dg = DeviceGraph::pack(&g, &gt, &tier)?;
    let engine = DeviceEngine::new(&store);
    let dev = engine.static_pagerank(&dg, &cfg, None)?;
    println!(
        "device: {} iterations in {:?} ({:.0} Kedges/s)",
        dev.iterations,
        dev.elapsed,
        g.num_edges() as f64 * dev.iterations as f64 / dev.elapsed.as_secs_f64() / 1e3
    );

    // 3. native CPU comparator
    let nat = native::static_pagerank(&g, &gt, &cfg, None);
    println!("native: {} iterations in {:?}", nat.iterations, nat.elapsed);

    // 4. agreement + top ranks
    let err = l1_distance(&dev.ranks, &nat.ranks)?;
    println!("L1(device, native) = {err:.3e}");
    assert!(err < 1e-9, "engines disagree");

    let mut idx: Vec<usize> = (0..dev.ranks.len()).collect();
    idx.sort_by(|&a, &b| dev.ranks[b].total_cmp(&dev.ranks[a]));
    println!("\ntop-5 vertices by rank:");
    for &v in idx.iter().take(5) {
        println!(
            "  v{v:<8} rank {:.6e}  in-degree {}",
            dev.ranks[v],
            gt.degree(v as u32)
        );
    }
    println!("\nquickstart OK");
    Ok(())
}
