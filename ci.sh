#!/usr/bin/env bash
# CI gate: build, test, lint. Run from the repo root.
#
#   ./ci.sh            # full gate
#   SKIP_CLIPPY=1 ./ci.sh   # build + test only (e.g. clippy not installed)
#
# Tier-1 (ROADMAP.md) is `cargo build --release && cargo test -q`; clippy
# rides along with -D warnings so lint regressions fail the gate too.
set -euo pipefail
cd "$(dirname "$0")"

if ! command -v cargo >/dev/null 2>&1; then
    echo "ci.sh: cargo not found on PATH — install the Rust toolchain first" >&2
    exit 1
fi

echo "=== cargo build --release ==="
cargo build --release

# Determinism gate: the worker-lane count (PAGERANK_THREADS), the SIMD
# backend (PAGERANK_SIMD: 0 = portable scalar loops, 1 = detected vector
# unit) and the CSR maintenance mode (PAGERANK_CSR: rebuild = per-update
# to_csr + transpose, incremental = O(batch) dyncsr patches) are pinned per
# run. tests/pool_determinism.rs writes a digest of every engine's — and
# the serving coordinator's — rank bits to
# rust/target/rank_digest_t<N>_s<S>_c<M>.txt; the full suite runs on two
# diagonal combos and the determinism matrix alone fills in the
# off-diagonals, then all digests are diffed: any schedule-, thread-count-,
# instruction-path- or CSR-layout-dependent bit anywhere in the stack
# fails the gate.
rm -f rust/target/rank_digest_t*.txt

echo "=== cargo test -q [PAGERANK_THREADS=1 PAGERANK_SIMD=0 PAGERANK_CSR=rebuild] (dev profile: debug assertions on) ==="
PAGERANK_THREADS=1 PAGERANK_SIMD=0 PAGERANK_CSR=rebuild cargo test -q

echo "=== cargo test -q [PAGERANK_THREADS=8 PAGERANK_SIMD=1 PAGERANK_CSR=incremental] ==="
PAGERANK_THREADS=8 PAGERANK_SIMD=1 PAGERANK_CSR=incremental cargo test -q

echo "=== cargo test -q --test pool_determinism [threads/simd/csr off-diagonals] ==="
PAGERANK_THREADS=1 PAGERANK_SIMD=1 PAGERANK_CSR=incremental cargo test -q --test pool_determinism
PAGERANK_THREADS=8 PAGERANK_SIMD=0 PAGERANK_CSR=rebuild cargo test -q --test pool_determinism
PAGERANK_THREADS=1 PAGERANK_SIMD=0 PAGERANK_CSR=incremental cargo test -q --test pool_determinism
PAGERANK_THREADS=8 PAGERANK_SIMD=1 PAGERANK_CSR=rebuild cargo test -q --test pool_determinism

echo "=== golden rank digest: threads {1,8} x simd {0,1} x csr {rebuild,incremental} ==="
for f in rust/target/rank_digest_t8_s1_ci.txt \
         rust/target/rank_digest_t1_s1_ci.txt \
         rust/target/rank_digest_t8_s0_cr.txt \
         rust/target/rank_digest_t1_s0_ci.txt \
         rust/target/rank_digest_t8_s1_cr.txt; do
    diff -u rust/target/rank_digest_t1_s0_cr.txt "$f"
done
echo "rank digests identical across thread counts, SIMD backends and CSR modes"

echo "=== cargo test -q --test robustness (fault-injection suite) ==="
cargo test -q --test robustness

if [ "${SKIP_CLIPPY:-0}" != "1" ]; then
    if cargo clippy --version >/dev/null 2>&1; then
        echo "=== cargo clippy --all-targets -- -D warnings ==="
        cargo clippy --all-targets -- -D warnings
    else
        echo "ci.sh: cargo-clippy not installed; skipping lint (set SKIP_CLIPPY=1 to silence)" >&2
    fi
fi

echo "ci.sh: OK"
