#!/usr/bin/env bash
# CI gate: build, test, lint. Run from the repo root.
#
#   ./ci.sh            # full gate
#   SKIP_CLIPPY=1 ./ci.sh   # build + test only (e.g. clippy not installed)
#
# Tier-1 (ROADMAP.md) is `cargo build --release && cargo test -q`; clippy
# rides along with -D warnings so lint regressions fail the gate too.
set -euo pipefail
cd "$(dirname "$0")"

if ! command -v cargo >/dev/null 2>&1; then
    echo "ci.sh: cargo not found on PATH — install the Rust toolchain first" >&2
    exit 1
fi

echo "=== cargo build --release ==="
cargo build --release

# Determinism gate: the full suite runs twice with the worker-lane count
# pinned via PAGERANK_THREADS. tests/pool_determinism.rs writes a digest of
# every engine's rank bits to rust/target/rank_digest_t<N>.txt; any
# schedule- or thread-count-dependent bit anywhere in the stack makes the
# two files differ and fails the gate.
rm -f rust/target/rank_digest_t*.txt

echo "=== cargo test -q [PAGERANK_THREADS=1] (dev profile: debug assertions on) ==="
PAGERANK_THREADS=1 cargo test -q

echo "=== cargo test -q [PAGERANK_THREADS=8] ==="
PAGERANK_THREADS=8 cargo test -q

echo "=== golden rank digest: t1 vs t8 ==="
diff -u rust/target/rank_digest_t1.txt rust/target/rank_digest_t8.txt
echo "rank digests identical across thread counts"

echo "=== cargo test -q --test robustness (fault-injection suite) ==="
cargo test -q --test robustness

if [ "${SKIP_CLIPPY:-0}" != "1" ]; then
    if cargo clippy --version >/dev/null 2>&1; then
        echo "=== cargo clippy --all-targets -- -D warnings ==="
        cargo clippy --all-targets -- -D warnings
    else
        echo "ci.sh: cargo-clippy not installed; skipping lint (set SKIP_CLIPPY=1 to silence)" >&2
    fi
fi

echo "ci.sh: OK"
