"""Device graph formats: the layout contract between Python (build time) and Rust (run time).

Everything the AOT artifacts consume is a fixed-shape, padded view of a graph.
This module is the single source of truth for that layout; ``rust/src/runtime/tier.rs``
mirrors it exactly, and ``artifacts/manifest.json`` (written by ``aot.py``)
records the shapes so Rust can assert against them.

Tier layout (all shapes fixed per tier; ``V`` and ``ECAP`` are powers of two):

- vertex ids are ``int32``; ranks are ``float64`` (paper uses 32-bit ids,
  64-bit ranks, Section 5.1.2).
- the *sentinel* vertex is index ``V - 1``. A graph with ``n`` real vertices
  fits a tier iff ``n <= V - 1`` and ``m <= ECAP`` edges. All index padding
  points at the sentinel, whose contribution is always 0 because
  ``outdeg_inv[V-1] == 0``.
- ``ell_idx   : i32[V, W]`` — row ``v`` holds the in-neighbors of ``v`` if
  ``indeg(v) <= W`` (a *low in-degree* vertex), padded with sentinels; rows of
  high in-degree vertices are all-sentinel. This feeds the
  "thread-per-vertex" analog kernel (``ell_block_sum``).
- ``hub_edges : i32[NC, C]``, ``hub_seg : i32[NC]`` — the in-neighbors of
  each *high in-degree* vertex (``indeg > W``), split into chunks of ``C``;
  ``hub_seg[row]`` is the destination vertex id (padding rows point at the
  sentinel). This feeds the "block-per-vertex" analog: a partial sum per
  chunk (same Pallas kernel, different tiling) + a tiny segment combine.
  ``NC = ECAP / 16`` fits whenever hub edges <= ~ECAP/2 (chunks <=
  hubE/16 + hubE/17); the packer retries the next tier up on overflow.
- ``out_ell_idx / out_hub_edges / out_hub_seg`` — the same structure over
  *out*-neighbors, partitioned by out-degree. Used by the scatter variant of
  frontier expansion (the paper partitions expansion by out-degree).
- ``te_src / te_dst : i32[ECAP]`` — the flat edge list of G (u -> v), used by
  the "Don't Partition" ablation (Figure 1) and the flat expansion variant.
- ``outdeg_inv : f64[V]`` — 1/outdeg for real vertices (every vertex has a
  self-loop, so outdeg >= 1), 0 for padding and the sentinel.
- ``valid : f64[V]`` — 1.0 for real vertices, else 0.
- ``inv_n : f64[1]`` — 1/n (n = number of real vertices).
"""

from dataclasses import dataclass

import numpy as np

#: degree threshold D_P: in/out-degree <= D_P is handled by the ELL
#: ("thread-per-vertex") kernel; above it, by the chunked hub kernel.
DEGREE_THRESHOLD = 16
#: ELL width (== D_P so any low-degree row fits exactly).
ELL_WIDTH = 16
#: hub chunk width.
CHUNK_WIDTH = 16


@dataclass(frozen=True)
class Tier:
    """A fixed-shape artifact size class."""

    name: str
    v: int  # vertex capacity, incl. sentinel slot V-1
    ecap: int  # edge capacity

    @property
    def w(self) -> int:
        return ELL_WIDTH

    @property
    def c(self) -> int:
        return CHUNK_WIDTH

    @property
    def nc(self) -> int:
        # chunk-row capacity: covers hub edges up to ~ECAP/2 (chunks <=
        # hubE/16 + hubE/17 ~= hubE/8.2). Degenerate hub-heavy graphs
        # overflow the packer, which retries one tier up (2x ECAP). Halving
        # this from the safe ECAP/8 bound halves the fixed per-iteration
        # hub-gather work — see EXPERIMENTS.md §Perf.
        return self.ecap // 16

    @property
    def wl_cap(self) -> int:
        # worklist-compacted step capacity (affected vertex ids).
        return self.v // 16

    @property
    def wl_chunk_cap(self) -> int:
        # worklist-compacted hub chunk row capacity.
        return self.nc // 16

    def fits(self, n: int, m: int) -> bool:
        return n <= self.v - 1 and m <= self.ecap


#: Tier set compiled by aot.py (vertex capacity 2^k, edge capacity 16x).
#: Fixed shapes mean padded work, so tiers are spaced one octave apart to cap
#: the padding tax at ~2x; graphs larger than the biggest tier fall back to
#: the Rust native engine.
TIERS = (
    Tier("t10", 1 << 10, 1 << 14),
    Tier("t12", 1 << 12, 1 << 16),
    Tier("t13", 1 << 13, 1 << 17),
    Tier("t14", 1 << 14, 1 << 18),
    Tier("t15", 1 << 15, 1 << 19),
    Tier("t16", 1 << 16, 1 << 20),
)


def tier_by_name(name: str) -> Tier:
    for t in TIERS:
        if t.name == name:
            return t
    raise KeyError(name)


def smallest_fitting_tier(n: int, m: int) -> Tier | None:
    for t in TIERS:
        if t.fits(n, m):
            return t
    return None


def _check_adj(adj: list[list[int]], n: int) -> None:
    assert len(adj) == n
    for vs in adj:
        for u in vs:
            assert 0 <= u < n


def transpose_adj(adj: list[list[int]]) -> list[list[int]]:
    n = len(adj)
    tadj: list[list[int]] = [[] for _ in range(n)]
    for u, vs in enumerate(adj):
        for v in vs:
            tadj[v].append(u)
    return tadj


def build_ell_and_hubs(adj: list[list[int]], tier: Tier):
    """Partition ``adj`` rows by degree into (ELL matrix, hub chunks, hub segs).

    Returns ``(ell_idx [V,W] i32, hub_edges [NC,C] i32, hub_seg [NC] i32)``.
    Row v of ``ell_idx`` is adj[v] (sentinel-padded) when ``len(adj[v]) <= W``,
    else all-sentinel with adj[v] routed to hub chunks with segment id v.
    """
    v_cap, w, c, nc = tier.v, tier.w, tier.c, tier.nc
    sentinel = v_cap - 1
    n = len(adj)
    assert n <= sentinel, f"graph n={n} exceeds tier {tier.name} capacity"

    ell = np.full((v_cap, w), sentinel, dtype=np.int32)
    hub_edges = np.full((nc, c), sentinel, dtype=np.int32)
    hub_seg = np.full((nc,), sentinel, dtype=np.int32)

    row = 0
    for v, nbrs in enumerate(adj):
        d = len(nbrs)
        if d <= w:
            if d:
                ell[v, :d] = np.asarray(nbrs, dtype=np.int32)
        else:
            for off in range(0, d, c):
                chunk = nbrs[off : off + c]
                # row NC-1 stays unused: it is the sentinel target of padded
                # worklist chunk ids (its edges are all-sentinel, seg = V-1).
                assert row < nc - 1, f"hub chunk overflow in tier {tier.name}"
                hub_edges[row, : len(chunk)] = np.asarray(chunk, dtype=np.int32)
                hub_seg[row] = v
                row += 1
    return ell, hub_edges, hub_seg


def build_flat_edges(adj: list[list[int]], tier: Tier):
    """Flat (src, dst) edge list of G, sentinel-padded to ECAP."""
    sentinel = tier.v - 1
    src = np.full((tier.ecap,), sentinel, dtype=np.int32)
    dst = np.full((tier.ecap,), sentinel, dtype=np.int32)
    i = 0
    for u, vs in enumerate(adj):
        for v in vs:
            assert i < tier.ecap, f"edge overflow in tier {tier.name}"
            src[i] = u
            dst[i] = v
            i += 1
    return src, dst


def build_device_graph(adj: list[list[int]], tier: Tier) -> dict[str, np.ndarray]:
    """Build every tier-shaped array the artifacts consume, from an
    out-adjacency list (self-loops must already be present; no dead ends)."""
    n = len(adj)
    _check_adj(adj, n)
    for v, vs in enumerate(adj):
        assert len(vs) >= 1, f"dead end at vertex {v}: add self-loops first"

    tadj = transpose_adj(adj)
    ell_idx, hub_edges, hub_seg = build_ell_and_hubs(tadj, tier)  # in-neighbors
    out_ell, out_hub_edges, out_hub_seg = build_ell_and_hubs(adj, tier)
    te_src, te_dst = build_flat_edges(adj, tier)

    outdeg_inv = np.zeros((tier.v,), dtype=np.float64)
    valid = np.zeros((tier.v,), dtype=np.float64)
    for v in range(n):
        outdeg_inv[v] = 1.0 / len(adj[v])
        valid[v] = 1.0

    return {
        "ell_idx": ell_idx,
        "hub_edges": hub_edges,
        "hub_seg": hub_seg,
        "out_ell_idx": out_ell,
        "out_hub_edges": out_hub_edges,
        "out_hub_seg": out_hub_seg,
        "te_src": te_src,
        "te_dst": te_dst,
        "outdeg_inv": outdeg_inv,
        "valid": valid,
        "inv_n": np.array([1.0 / n], dtype=np.float64),
    }


def pad_vec(x: np.ndarray, v_cap: int, dtype=np.float64) -> np.ndarray:
    out = np.zeros((v_cap,), dtype=dtype)
    out[: x.shape[0]] = x
    return out
