"""L2: one PageRank iteration (and frontier expansion) as pure JAX functions.

Each function here is shape-specialized to a :class:`~compile.formats.Tier`
and is AOT-lowered by ``aot.py`` to an HLO-text artifact that the Rust
coordinator executes via PJRT. The L1 kernels (``kernels/``) are called from
these functions so they lower into the same HLO; ``impl`` selects the Pallas
or the XLA-fused kernel implementation (see ``kernels/fused.py``).

Artifact variants (see DESIGN.md §7 and the paper's Algorithms 1-3, 5):

- ``step_plain``  — Eq. 1 over all vertices (Static and Naive-dynamic).
- ``step_dt``     — Eq. 1 restricted to a fixed affected mask (Dynamic
                    Traversal).
- ``step_df``     — Eq. 1 over the affected set + frontier marking (DF).
- ``step_dfp``    — Eq. 2 (closed-loop self-loop formula) + frontier marking
                    + pruning (DF-P).
- ``step_df_wl`` / ``step_dfp_wl`` — worklist-compacted variants: the
                    affected vertex ids (and affected hub chunk rows) arrive
                    as fixed-capacity index vectors, so gather work scales
                    with the frontier instead of |V| — the fixed-shape analog
                    of the GPU's per-vertex ``if not affected: continue``.
- ``step_df_nopart`` / ``step_dfp_nopart`` — "Don't Partition" ablation:
                    contributions via a flat edge-list segment sum instead of
                    the partitioned ELL + hub-chunk kernel pair (Figure 1).
- ``expand_pull``    — frontier expansion as an atomics-free gather over the
                    in-ELL/hub structure (our TPU-friendly adaptation).
- ``expand_scatter`` — the paper's push form, partitioned by out-degree.
- ``expand_scatter_wl`` — worklist-compacted push expansion.
- ``expand_flat``    — unpartitioned push over the flat edge list (ablation).

All tolerances/constants are baked at lowering time (paper §5.1.2):
alpha=0.85, tau_f=tau_p=1e-6. The iteration tolerance check happens in Rust
on the returned L-infinity delta.
"""

import jax
import jax.numpy as jnp

from . import kernels
from .formats import Tier

jax.config.update("jax_enable_x64", True)

ALPHA = 0.85
TAU_FRONTIER = 1e-6
TAU_PRUNE = 1e-6

F64 = jnp.float64
I32 = jnp.int32

#: worklist capacity = V / WL_DIV (and NC / WL_DIV chunk rows). Rust falls
#: back to the full-shape step whenever the frontier outgrows this.
WL_DIV = 16


# --- shared pieces --------------------------------------------------------


def _incoming_partitioned(k, contrib, ell_idx, hub_edges, hub_seg, v_cap):
    """c[v] = sum_{u in G.in(v)} contrib[u] via the paper's two-kernel split:
    low in-degree rows through the ELL kernel ("thread-per-vertex"), hub
    chunks through the same kernel + a segment combine ("block-per-vertex").
    """
    c_low = k.ell_block_sum(contrib, ell_idx)  # [V]
    partials = k.ell_block_sum(contrib, hub_edges)  # [NC]
    c_hub = jax.ops.segment_sum(partials, hub_seg, num_segments=v_cap)
    return c_low + c_hub


def _incoming_flat(contrib, te_src, te_dst, v_cap):
    """Unpartitioned ("Don't Partition") contribution sum: one edge-parallel
    segmented reduction over the flat edge list."""
    return jax.ops.segment_sum(contrib[te_src], te_dst, num_segments=v_cap)


def _rank_candidate(r, c, outdeg_inv, valid, inv_n, *, prune):
    c0 = (1.0 - ALPHA) * inv_n[0]
    if prune:
        # Eq. 2: the self-loop contribution (present in c, since every vertex
        # carries a self-loop edge) is moved to the closed form.
        k = c - r * outdeg_inv
        return valid * (ALPHA * k + c0) / (1.0 - ALPHA * outdeg_inv)
    return valid * (c0 + ALPHA * c)  # Eq. 1


def _finish_masked(k, r, cand, aff, *, prune):
    """Frontier/prune bookkeeping shared by DF and DF-P (Algorithm 3)."""
    mask = aff > 0
    r_new = jnp.where(mask, cand, r)
    denom = jnp.maximum(r_new, r)
    rel = jnp.where(denom > 0, jnp.abs(r_new - r) / denom, 0.0)
    delta_n = jnp.where(mask & (rel > TAU_FRONTIER), 1.0, 0.0)
    if prune:
        aff_out = jnp.where(mask & (rel <= TAU_PRUNE), 0.0, aff)
    else:
        aff_out = aff
    linf = k.linf_delta(r_new, r)
    return r_new, aff_out, delta_n, linf


# --- step variants --------------------------------------------------------


def make_step_plain(tier: Tier, impl: str = "fused"):
    k = kernels.get_impl(impl)

    def step_plain(r, outdeg_inv, valid, inv_n, ell_idx, hub_edges, hub_seg):
        contrib = r * outdeg_inv
        c = _incoming_partitioned(k, contrib, ell_idx, hub_edges, hub_seg, tier.v)
        r_new = _rank_candidate(r, c, outdeg_inv, valid, inv_n, prune=False)
        linf = k.linf_delta(r_new, r)
        return r_new, linf

    return step_plain


def make_step_dt(tier: Tier, impl: str = "fused"):
    k = kernels.get_impl(impl)

    def step_dt(r, outdeg_inv, valid, inv_n, ell_idx, hub_edges, hub_seg, aff):
        contrib = r * outdeg_inv
        c = _incoming_partitioned(k, contrib, ell_idx, hub_edges, hub_seg, tier.v)
        cand = _rank_candidate(r, c, outdeg_inv, valid, inv_n, prune=False)
        r_new = jnp.where(aff > 0, cand, r)
        linf = k.linf_delta(r_new, r)
        return r_new, linf

    return step_dt


def make_step_df(tier: Tier, *, prune: bool, partitioned: bool = True,
                 impl: str = "fused"):
    k = kernels.get_impl(impl)

    if partitioned:

        def step(r, outdeg_inv, valid, inv_n, ell_idx, hub_edges, hub_seg, aff):
            contrib = r * outdeg_inv
            c = _incoming_partitioned(
                k, contrib, ell_idx, hub_edges, hub_seg, tier.v
            )
            cand = _rank_candidate(r, c, outdeg_inv, valid, inv_n, prune=prune)
            return _finish_masked(k, r, cand, aff, prune=prune)

    else:

        def step(r, outdeg_inv, valid, inv_n, te_src, te_dst, aff):
            contrib = r * outdeg_inv
            c = _incoming_flat(contrib, te_src, te_dst, tier.v)
            cand = _rank_candidate(r, c, outdeg_inv, valid, inv_n, prune=prune)
            return _finish_masked(k, r, cand, aff, prune=prune)

    return step


def make_step_df_wl(tier: Tier, *, prune: bool, impl: str = "fused"):
    """Worklist-compacted DF/DF-P step: only the (<= V/WL_DIV) affected
    vertices' ELL rows and (<= NC/WL_DIV) affected hub chunk rows are
    gathered. ``wl`` entries must cover every vertex with aff=1 (padding =
    sentinel, whose ELL row is all-sentinel); ``wl_chunks`` every hub chunk
    row whose segment vertex is affected (padding = NC-1, which the packer
    keeps unused/sentinel)."""
    k = kernels.get_impl(impl)
    del k  # gather shapes here are worklist-sized; fused forms only.

    def step(r, outdeg_inv, valid, inv_n, ell_idx, hub_edges, hub_seg, aff,
             wl, wl_chunks):
        contrib = r * outdeg_inv
        rows = ell_idx[wl]  # [K, W]
        c_rows = contrib[rows].sum(axis=1)  # [K]
        ch = hub_edges[wl_chunks]  # [KC, C]
        partials = contrib[ch].sum(axis=1)  # [KC]
        c = jnp.zeros((tier.v,), dtype=jnp.float64).at[wl].add(c_rows)
        c = c.at[hub_seg[wl_chunks]].add(partials)
        cand = _rank_candidate(r, c, outdeg_inv, valid, inv_n, prune=prune)
        fused_k = kernels.get_impl("fused")
        return _finish_masked(fused_k, r, cand, aff, prune=prune)

    return step


# --- frontier expansion variants ------------------------------------------


def make_expand_pull(tier: Tier, impl: str = "fused"):
    """dv'[v] = dv[v] or (exists u in G.in(v) with dn[u]) — gather form, one
    write per vertex, no scatter contention. Uses the same in-side ELL/hub
    arrays as rank computation (work proportional to in-degree)."""
    k = kernels.get_impl(impl)

    def expand_pull(dv, dn, ell_idx, hub_edges, hub_seg):
        m_low = k.ell_block_max(dn, ell_idx)  # [V]
        partials = k.ell_block_max(dn, hub_edges)  # [NC]
        m_hub = jax.ops.segment_max(partials, hub_seg, num_segments=tier.v)
        m_hub = jnp.maximum(m_hub, 0.0)  # empty segments come back as -inf
        return jnp.maximum(dv, jnp.maximum(m_low, m_hub))

    return expand_pull


def make_expand_scatter(tier: Tier):
    """The paper's push form (Algorithm 5), partitioned by out-degree: low
    out-degree rows scatter their flag to <=W out-neighbors; hub chunks
    scatter per-chunk. Scatter-max over possibly-duplicate targets models the
    paper's benign write races."""

    def expand_scatter(dv, dn, out_ell_idx, out_hub_edges, out_hub_seg):
        dv, dn = jnp.asarray(dv), jnp.asarray(dn)
        flags_rows = jnp.broadcast_to(dn[:, None], out_ell_idx.shape)
        out = dv.at[out_ell_idx.reshape(-1)].max(flags_rows.reshape(-1))
        hub_flags = jnp.broadcast_to(
            dn[out_hub_seg][:, None], out_hub_edges.shape
        )
        out = out.at[out_hub_edges.reshape(-1)].max(hub_flags.reshape(-1))
        return out

    return expand_scatter


def make_expand_scatter_wl(tier: Tier):
    """Worklist-compacted push expansion: only the ELL rows / hub chunks of
    vertices with dn=1 are touched."""

    def expand_scatter_wl(dv, dn, out_ell_idx, out_hub_edges, out_hub_seg,
                          wl, wl_chunks):
        dv, dn = jnp.asarray(dv), jnp.asarray(dn)
        rows = out_ell_idx[wl]  # [K, W]
        flags = jnp.broadcast_to(dn[wl][:, None], rows.shape)
        out = dv.at[rows.reshape(-1)].max(flags.reshape(-1))
        ch = out_hub_edges[wl_chunks]  # [KC, C]
        cf = jnp.broadcast_to(dn[out_hub_seg[wl_chunks]][:, None], ch.shape)
        out = out.at[ch.reshape(-1)].max(cf.reshape(-1))
        return out

    return expand_scatter_wl


def make_expand_flat(tier: Tier):
    """Unpartitioned push over the flat edge list ("Don't Partition")."""

    def expand_flat(dv, dn, te_src, te_dst):
        dv, dn = jnp.asarray(dv), jnp.asarray(dn)
        return dv.at[te_dst].max(dn[te_src])

    return expand_flat


# --- standalone L1 kernel artifacts (Pallas path, integration-tested) ------


def make_kernel_ell_sum(tier: Tier):
    def kernel_ell_sum(contrib, ell_idx):
        return kernels.ell_block_sum(contrib, ell_idx)

    return kernel_ell_sum


def make_kernel_linf(tier: Tier):
    def kernel_linf(a, b):
        return kernels.linf_delta(a, b)

    return kernel_linf


# --- artifact registry -----------------------------------------------------


# --- packed (single-output) artifact wrappers -------------------------------
#
# The Rust runtime chains PJRT *buffers* between launches (device-resident
# loop). The xla crate cannot split tuple-shaped output buffers, so every
# production artifact takes and returns ONE packed f64 state vector:
#
#   state1 = [r | linf]              (V+1,)   — plain / dt steps
#   state3 = [r | aff | dn | linf]   (3V+1,)  — df / dfp steps + expansion
#
# plus tiny ``peek_*`` programs that slice out the convergence scalar (or the
# flag segments, for worklist construction) so the per-iteration host
# transfer is 8 bytes instead of the whole state.


def _unpack1(state, v):
    return state[:v]


def _unpack3(state, v):
    return state[:v], state[v : 2 * v], state[2 * v : 3 * v]


def make_step_plain_packed(tier: Tier, impl: str = "fused"):
    inner = make_step_plain(tier, impl)
    v = tier.v

    def step(state, outdeg_inv, valid, inv_n, ell_idx, hub_edges, hub_seg):
        r = _unpack1(state, v)
        r2, linf = inner(r, outdeg_inv, valid, inv_n, ell_idx, hub_edges, hub_seg)
        return jnp.concatenate([r2, linf])

    return step


def make_step_dt_packed(tier: Tier, impl: str = "fused"):
    inner = make_step_dt(tier, impl)
    v = tier.v

    def step(state, outdeg_inv, valid, inv_n, ell_idx, hub_edges, hub_seg, aff):
        r = _unpack1(state, v)
        r2, linf = inner(r, outdeg_inv, valid, inv_n, ell_idx, hub_edges, hub_seg, aff)
        return jnp.concatenate([r2, linf])

    return step


def make_step_df_packed(tier: Tier, *, prune: bool, partitioned: bool = True,
                        impl: str = "fused"):
    inner = make_step_df(tier, prune=prune, partitioned=partitioned, impl=impl)
    v = tier.v

    if partitioned:

        def step(state, outdeg_inv, valid, inv_n, ell_idx, hub_edges, hub_seg):
            r, aff, _dn = _unpack3(state, v)
            r2, aff2, dn2, linf = inner(
                r, outdeg_inv, valid, inv_n, ell_idx, hub_edges, hub_seg, aff
            )
            return jnp.concatenate([r2, aff2, dn2, linf])

    else:

        def step(state, outdeg_inv, valid, inv_n, te_src, te_dst):
            r, aff, _dn = _unpack3(state, v)
            r2, aff2, dn2, linf = inner(
                r, outdeg_inv, valid, inv_n, te_src, te_dst, aff
            )
            return jnp.concatenate([r2, aff2, dn2, linf])

    return step


def make_step_df_wl_packed(tier: Tier, *, prune: bool, impl: str = "fused"):
    inner = make_step_df_wl(tier, prune=prune, impl=impl)
    v = tier.v

    def step(state, outdeg_inv, valid, inv_n, ell_idx, hub_edges, hub_seg,
             wl, wl_chunks):
        r, aff, _dn = _unpack3(state, v)
        r2, aff2, dn2, linf = inner(
            r, outdeg_inv, valid, inv_n, ell_idx, hub_edges, hub_seg, aff,
            wl, wl_chunks,
        )
        return jnp.concatenate([r2, aff2, dn2, linf])

    return step


def _repack_expand(state, v, aff2):
    # r, dn and linf pass through; only the affected flags change.
    return jnp.concatenate([state[:v], aff2, state[2 * v :]])


def make_expand_pull_packed(tier: Tier, impl: str = "fused"):
    inner = make_expand_pull(tier, impl)
    v = tier.v

    def expand(state, ell_idx, hub_edges, hub_seg):
        _r, aff, dn = _unpack3(state, v)
        return _repack_expand(state, v, inner(aff, dn, ell_idx, hub_edges, hub_seg))

    return expand


def make_expand_scatter_packed(tier: Tier):
    inner = make_expand_scatter(tier)
    v = tier.v

    def expand(state, out_ell_idx, out_hub_edges, out_hub_seg):
        _r, aff, dn = _unpack3(state, v)
        return _repack_expand(
            state, v, inner(aff, dn, out_ell_idx, out_hub_edges, out_hub_seg)
        )

    return expand


def make_expand_scatter_wl_packed(tier: Tier):
    inner = make_expand_scatter_wl(tier)
    v = tier.v

    def expand(state, out_ell_idx, out_hub_edges, out_hub_seg, wl, wl_chunks):
        _r, aff, dn = _unpack3(state, v)
        return _repack_expand(
            state, v,
            inner(aff, dn, out_ell_idx, out_hub_edges, out_hub_seg, wl, wl_chunks),
        )

    return expand


def make_expand_flat_packed(tier: Tier):
    inner = make_expand_flat(tier)
    v = tier.v

    def expand(state, te_src, te_dst):
        _r, aff, dn = _unpack3(state, v)
        return _repack_expand(state, v, inner(aff, dn, te_src, te_dst))

    return expand


def make_peek_last(tier: Tier, state_len: int):
    def peek(state):
        return state[state_len - 1 : state_len]

    return peek


def make_peek_aff_dn(tier: Tier):
    v = tier.v

    def peek(state):
        return state[v : 3 * v]

    return peek


def artifact_specs(tier: Tier, impl: str = "fused"):
    """Every artifact for a tier: name -> (fn, inputs, output_names).

    All programs return a single packed array (see the packed-wrapper
    section above); the input order is the execute() argument order on the
    Rust side and is recorded in the manifest.
    """
    v, w, c, nc, ecap = tier.v, tier.w, tier.c, tier.nc, tier.ecap
    kcap, kc_cap = tier.wl_cap, tier.wl_chunk_cap
    state1 = ("state", (v + 1,), F64)
    state3 = ("state", (3 * v + 1,), F64)
    odi = ("outdeg_inv", (v,), F64)
    valid = ("valid", (v,), F64)
    inv_n = ("inv_n", (1,), F64)
    ell = ("ell_idx", (v, w), I32)
    hub_e = ("hub_edges", (nc, c), I32)
    hub_s = ("hub_seg", (nc,), I32)
    oell = ("out_ell_idx", (v, w), I32)
    ohub_e = ("out_hub_edges", (nc, c), I32)
    ohub_s = ("out_hub_seg", (nc,), I32)
    tsrc = ("te_src", (ecap,), I32)
    tdst = ("te_dst", (ecap,), I32)
    aff = ("aff", (v,), F64)
    wl = ("wl", (kcap,), I32)
    wlc = ("wl_chunks", (kc_cap,), I32)

    part_graph = [ell, hub_e, hub_s]
    return {
        "step_plain": (
            make_step_plain_packed(tier, impl),
            [state1, odi, valid, inv_n, *part_graph],
            ["state"],
        ),
        "step_dt": (
            make_step_dt_packed(tier, impl),
            [state1, odi, valid, inv_n, *part_graph, aff],
            ["state"],
        ),
        "step_df": (
            make_step_df_packed(tier, prune=False, impl=impl),
            [state3, odi, valid, inv_n, *part_graph],
            ["state"],
        ),
        "step_dfp": (
            make_step_df_packed(tier, prune=True, impl=impl),
            [state3, odi, valid, inv_n, *part_graph],
            ["state"],
        ),
        "step_df_wl": (
            make_step_df_wl_packed(tier, prune=False, impl=impl),
            [state3, odi, valid, inv_n, *part_graph, wl, wlc],
            ["state"],
        ),
        "step_dfp_wl": (
            make_step_df_wl_packed(tier, prune=True, impl=impl),
            [state3, odi, valid, inv_n, *part_graph, wl, wlc],
            ["state"],
        ),
        "step_df_nopart": (
            make_step_df_packed(tier, prune=False, partitioned=False, impl=impl),
            [state3, odi, valid, inv_n, tsrc, tdst],
            ["state"],
        ),
        "step_dfp_nopart": (
            make_step_df_packed(tier, prune=True, partitioned=False, impl=impl),
            [state3, odi, valid, inv_n, tsrc, tdst],
            ["state"],
        ),
        "expand_pull": (
            make_expand_pull_packed(tier, impl),
            [state3, *part_graph],
            ["state"],
        ),
        "expand_scatter": (
            make_expand_scatter_packed(tier),
            [state3, oell, ohub_e, ohub_s],
            ["state"],
        ),
        "expand_scatter_wl": (
            make_expand_scatter_wl_packed(tier),
            [state3, oell, ohub_e, ohub_s, wl, wlc],
            ["state"],
        ),
        "expand_flat": (
            make_expand_flat_packed(tier),
            [state3, tsrc, tdst],
            ["state"],
        ),
        "peek_linf1": (make_peek_last(tier, v + 1), [state1], ["linf"]),
        "peek_linf3": (make_peek_last(tier, 3 * v + 1), [state3], ["linf"]),
        "peek_aff_dn": (make_peek_aff_dn(tier), [state3], ["aff_dn"]),
        # standalone Pallas kernel artifacts (integration smoke + micro-bench)
        "kernel_ell_sum": (
            make_kernel_ell_sum(tier),
            [("contrib", (v,), F64), ell],
            ["row_sums"],
        ),
        "kernel_linf": (
            make_kernel_linf(tier),
            [("a", (v,), F64), ("b", (v,), F64)],
            ["linf"],
        ),
    }
