"""AOT: lower every L2 artifact to HLO text + write the manifest.

Runs ONCE at build time (``make artifacts``); Python is never on the request
path. HLO *text* (not serialized HloModuleProto) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Usage: ``python -m compile.aot --out-dir ../artifacts [--tiers t10,t13,t16]``
"""

import argparse
import hashlib
import json
import os
import time

import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402
from .formats import (  # noqa: E402
    CHUNK_WIDTH,
    DEGREE_THRESHOLD,
    ELL_WIDTH,
    TIERS,
    Tier,
)


def to_hlo_text(lowered) -> str:
    # return_tuple=False: every artifact returns a single packed array, so
    # the Rust side can chain device-resident PJRT buffers between launches
    # (tuple-shaped output buffers cannot be split through the xla crate).
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def _dtype_name(dtype) -> str:
    import numpy as np

    return np.dtype(dtype).name  # "float64" / "int32"


def lower_tier(tier: Tier, out_dir: str, impl: str) -> list[dict]:
    entries = []
    for name, (fn, inputs, output_names) in model.artifact_specs(
        tier, impl=impl
    ).items():
        specs = [jax.ShapeDtypeStruct(shape, dtype) for _, shape, dtype in inputs]
        t0 = time.time()
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}_{tier.name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        print(
            f"  {fname:32s} {len(text) / 1024:8.1f} KiB "
            f"({time.time() - t0:.1f}s)"
        )
        entries.append(
            {
                "name": name,
                "tier": tier.name,
                "file": fname,
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
                "inputs": [
                    {
                        "name": in_name,
                        "shape": list(shape),
                        "dtype": _dtype_name(dtype),
                    }
                    for in_name, shape, dtype in inputs
                ],
                "outputs": output_names,
            }
        )
    return entries


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--tiers",
        default=",".join(t.name for t in TIERS),
        help="comma-separated tier names to lower",
    )
    ap.add_argument(
        "--impl",
        default="fused",
        choices=["fused", "pallas"],
        help="kernel implementation baked into the step/expand artifacts "
        "(the standalone kernel_* artifacts are always Pallas)",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    wanted = set(args.tiers.split(","))
    tiers = [t for t in TIERS if t.name in wanted]
    assert tiers, f"no tiers match {args.tiers}"

    manifest = {
        "format_version": 1,
        "kernel_impl": args.impl,
        "constants": {
            "alpha": model.ALPHA,
            "tau_frontier": model.TAU_FRONTIER,
            "tau_prune": model.TAU_PRUNE,
            "degree_threshold": DEGREE_THRESHOLD,
            "ell_width": ELL_WIDTH,
            "chunk_width": CHUNK_WIDTH,
        },
        "tiers": [
            {
                "name": t.name,
                "v": t.v,
                "ecap": t.ecap,
                "w": t.w,
                "c": t.c,
                "nc": t.nc,
                "wl_cap": t.wl_cap,
                "wl_chunk_cap": t.wl_chunk_cap,
            }
            for t in tiers
        ],
        "artifacts": [],
    }
    for tier in tiers:
        print(f"tier {tier.name}: V={tier.v} ECAP={tier.ecap} NC={tier.nc}")
        manifest["artifacts"].extend(lower_tier(tier, args.out_dir, args.impl))

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {len(manifest['artifacts'])} artifacts + manifest.json")


if __name__ == "__main__":
    main()
