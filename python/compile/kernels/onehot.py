"""L1 Pallas kernel (ablation): one-hot MXU segmented reduction for hub edges.

An alternative "block-per-vertex" adaptation: instead of per-hub chunk rows
(kernels/ell.py over ``hub_edges``), the hub edge list is kept flat and each
chunk's contributions are reduced into per-segment partials with a one-hot
matmul — on a real TPU this maps the irregular reduction onto the MXU
systolic array. It is quadratic in the number of segments per chunk, so it
only pays off when the hub count is small; the production artifacts use the
chunk-row formulation, and ``benches``/pytest compare the two
(EXPERIMENTS.md §Perf, kernel ablation).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

CHUNK = 256


def _onehot_kernel(contrib_ref, src_ref, seg_ref, o_ref, *, num_segments):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    contrib = contrib_ref[...]
    vals = contrib[src_ref[...]]  # [chunk]
    seg = seg_ref[...]  # [chunk]
    onehot = (seg[:, None] == jnp.arange(num_segments)[None, :]).astype(
        contrib.dtype
    )
    # [chunk] x [chunk, S] -> [S]: the MXU-friendly segmented reduction.
    o_ref[...] += vals @ onehot


def onehot_segment_sum(
    contrib: jax.Array, src: jax.Array, seg: jax.Array, num_segments: int
) -> jax.Array:
    """sum of ``contrib[src[e]]`` into segment ``seg[e]``; padding edges must
    point ``src`` at the sentinel (contribution 0). Returns f64[num_segments].
    """
    (e,) = src.shape
    chunk = min(CHUNK, e)
    assert e % chunk == 0
    return pl.pallas_call(
        functools.partial(_onehot_kernel, num_segments=num_segments),
        grid=(e // chunk,),
        in_specs=[
            pl.BlockSpec(contrib.shape, lambda i: (0,)),
            pl.BlockSpec((chunk,), lambda i: (i,)),
            pl.BlockSpec((chunk,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((num_segments,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((num_segments,), contrib.dtype),
        interpret=True,
    )(contrib, src, seg)
