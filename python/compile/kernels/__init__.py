"""L1 kernels (build-time only; lowered into the L2 HLO artifacts).

Two interchangeable implementations of the same interface:

- ``pallas``: the TPU-structural Pallas kernels (``ell.py``, ``norms.py``,
  ``onehot.py``) -- interpret=True, correctness-checked against ``ref.py``.
- ``fused``: the XLA-fused equivalents baked into production artifacts on
  the CPU-PJRT simulated GPU (see ``fused.py`` for why).

``get_impl(name)`` returns a namespace with ``ell_block_sum``,
``ell_block_max`` and ``linf_delta``.
"""

import types

from . import fused
from .ell import ell_block_sum, ell_block_max
from .norms import linf_delta
from .onehot import onehot_segment_sum

_PALLAS = types.SimpleNamespace(
    ell_block_sum=ell_block_sum,
    ell_block_max=ell_block_max,
    linf_delta=linf_delta,
)
_FUSED = types.SimpleNamespace(
    ell_block_sum=fused.ell_block_sum,
    ell_block_max=fused.ell_block_max,
    linf_delta=fused.linf_delta,
)


def get_impl(name: str):
    if name == "pallas":
        return _PALLAS
    if name == "fused":
        return _FUSED
    raise ValueError(f"unknown kernel impl {name!r}")


__all__ = [
    "ell_block_sum",
    "ell_block_max",
    "linf_delta",
    "onehot_segment_sum",
    "get_impl",
    "fused",
]
