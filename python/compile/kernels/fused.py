"""XLA-fused counterparts of the Pallas kernels (same math, same interface).

Why both exist: the Pallas kernels in ``ell.py``/``norms.py`` are the L1
artifact for a real TPU — their ``interpret=True`` CPU emulation, however,
executes gathers ~50x slower than the identical XLA-fused expression (each
grid step re-materializes refs; measured in EXPERIMENTS.md §Perf). Since the
CPU PJRT backend *is* our simulated GPU, the production artifacts bake these
fused forms, which lower to exactly the gather/reduce/scatter HLO a Mosaic
compilation of the Pallas kernels would produce. pytest asserts the two
implementations agree bit-for-bit on random inputs, and ``aot.py
--impl pallas`` can bake the Pallas path instead for structural validation.
"""

import jax
import jax.numpy as jnp


def ell_block_sum(contrib: jax.Array, idx: jax.Array) -> jax.Array:
    return contrib[idx].sum(axis=1)


def ell_block_max(flags: jax.Array, idx: jax.Array) -> jax.Array:
    return flags[idx].max(axis=1)


def linf_delta(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.max(jnp.abs(a - b))[None]
