"""L1 Pallas kernel: two-phase L-infinity norm of a rank delta.

Mirrors the paper's convergence detection (Section 4.1): a first kernel
computes the block-wise max of |R_new - R| and a second reduces the per-block
results. Here both phases live in one Pallas program: the grid walks blocks
of the rank vectors and max-accumulates into a single-element output block
(grid steps execute in order, so revisiting the output block is a reduction,
exactly like the paper's second kernel over the temporary buffer).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 1024


def _linf_kernel(a_ref, b_ref, o_ref):
    i = pl.program_id(0)
    m = jnp.max(jnp.abs(a_ref[...] - b_ref[...]))

    @pl.when(i == 0)
    def _init():
        o_ref[0] = m

    @pl.when(i > 0)
    def _acc():
        o_ref[0] = jnp.maximum(o_ref[0], m)


def linf_delta(a: jax.Array, b: jax.Array) -> jax.Array:
    """max_v |a[v] - b[v]| as an f64[1] array (shape kept rank-1 so the Rust
    side reads a plain vector)."""
    (n,) = a.shape
    block = min(BLOCK, n)
    assert n % block == 0
    return pl.pallas_call(
        _linf_kernel,
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), a.dtype),
        interpret=True,
    )(a, b)
