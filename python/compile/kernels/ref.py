"""Pure-jnp / numpy oracles for the Pallas kernels and the PageRank step.

Everything the kernels and L2 model compute has a dense, obviously-correct
counterpart here; pytest (with hypothesis sweeps) asserts allclose between
the two. ``naive_pagerank`` is additionally the end-to-end rank oracle used
by both the python and (via golden files) the Rust test suites.
"""

import numpy as np

ALPHA = 0.85
TAU = 1e-10
TAU_FRONTIER = 1e-6
TAU_PRUNE = 1e-6
MAX_ITERATIONS = 500


# --- kernel oracles -------------------------------------------------------


def ell_sum_ref(contrib: np.ndarray, idx: np.ndarray) -> np.ndarray:
    return contrib[idx].sum(axis=1)


def ell_max_ref(flags: np.ndarray, idx: np.ndarray) -> np.ndarray:
    return flags[idx].max(axis=1)


def linf_ref(a: np.ndarray, b: np.ndarray) -> float:
    return float(np.abs(a - b).max())


def segment_sum_ref(vals: np.ndarray, seg: np.ndarray, num_segments: int):
    out = np.zeros((num_segments,), dtype=vals.dtype)
    np.add.at(out, seg, vals)
    return out


# --- PageRank step oracle (adjacency-list semantics) ----------------------


def incoming_contrib_ref(r: np.ndarray, adj: list[list[int]]) -> np.ndarray:
    """c[v] = sum over in-neighbors u of r[u]/outdeg(u), computed by a plain
    push loop over the out-adjacency."""
    n = len(adj)
    c = np.zeros((n,), dtype=np.float64)
    for u, vs in enumerate(adj):
        if not vs:
            continue
        share = r[u] / len(vs)
        for v in vs:
            c[v] += share
    return c


def step_ref(
    r: np.ndarray,
    adj: list[list[int]],
    *,
    mode: str = "plain",
    aff: np.ndarray | None = None,
    alpha: float = ALPHA,
    tau_f: float = TAU_FRONTIER,
    tau_p: float = TAU_PRUNE,
):
    """One synchronous PageRank iteration per the paper's Algorithm 3.

    Returns ``(r_new, aff_out, delta_n, linf)``. ``mode`` in
    {"plain", "dt", "df", "dfp"}; plain ignores ``aff``.
    """
    n = len(adj)
    c = incoming_contrib_ref(r, adj)
    c0 = (1.0 - alpha) / n
    outdeg = np.array([len(vs) for vs in adj], dtype=np.float64)

    if mode == "dfp":
        # Eq. 2: closed-loop formula absorbing the self-loop.
        k = c - r / outdeg
        cand = (alpha * k + c0) / (1.0 - alpha / outdeg)
    else:
        cand = c0 + alpha * c  # Eq. 1

    if mode == "plain":
        r_new = cand
        aff_out = None
        delta_n = None
    else:
        assert aff is not None
        mask = aff > 0
        r_new = np.where(mask, cand, r)
        denom = np.maximum(r_new, r)
        rel = np.where(denom > 0, np.abs(r_new - r) / denom, 0.0)
        delta_n = (mask & (rel > tau_f)).astype(np.float64)
        aff_out = aff.copy()
        if mode == "dfp":
            aff_out = np.where(mask & (rel <= tau_p), 0.0, aff_out)

    linf = float(np.abs(r_new - r).max())
    return r_new, aff_out, delta_n, linf


def expand_ref(dv: np.ndarray, dn: np.ndarray, adj: list[list[int]]):
    """Mark out-neighbors of every vertex with dn set (Algorithm 5)."""
    out = dv.copy()
    for u, vs in enumerate(adj):
        if dn[u] > 0:
            for v in vs:
                out[v] = 1.0
    return out


def initial_affected_ref(n: int, deletions, insertions):
    """Algorithm 5 initialAffected: returns (dv, dn) f64[n] flags."""
    dv = np.zeros((n,), dtype=np.float64)
    dn = np.zeros((n,), dtype=np.float64)
    for u, v in deletions:
        dn[u] = 1.0
        dv[v] = 1.0
    for u, _v in insertions:
        dn[u] = 1.0
    return dv, dn


# --- end-to-end oracles ---------------------------------------------------


def naive_pagerank(
    adj: list[list[int]],
    *,
    r0: np.ndarray | None = None,
    alpha: float = ALPHA,
    tau: float = TAU,
    max_iter: int = MAX_ITERATIONS,
) -> tuple[np.ndarray, int]:
    """Synchronous pull power iteration; reference for Static/ND ranks."""
    n = len(adj)
    r = np.full((n,), 1.0 / n) if r0 is None else r0.astype(np.float64).copy()
    for it in range(max_iter):
        r_new, _, _, linf = step_ref(r, adj, mode="plain", alpha=alpha)
        r = r_new
        if linf <= tau:
            return r, it + 1
    return r, max_iter


def dynamic_frontier_pagerank(
    adj: list[list[int]],
    r0: np.ndarray,
    deletions,
    insertions,
    *,
    prune: bool,
    alpha: float = ALPHA,
    tau: float = TAU,
    tau_f: float = TAU_FRONTIER,
    tau_p: float = TAU_PRUNE,
    max_iter: int = MAX_ITERATIONS,
) -> tuple[np.ndarray, int]:
    """Reference DF / DF-P on the *updated* graph ``adj`` (Algorithm 2)."""
    n = len(adj)
    mode = "dfp" if prune else "df"
    dv, dn = initial_affected_ref(n, deletions, insertions)
    dv = expand_ref(dv, dn, adj)
    r = r0.astype(np.float64).copy()
    for it in range(max_iter):
        r_new, dv, dn, linf = step_ref(
            r, adj, mode=mode, aff=dv, alpha=alpha, tau_f=tau_f, tau_p=tau_p
        )
        r = r_new
        if linf <= tau:
            return r, it + 1
        dv = expand_ref(dv, dn, adj)
    return r, max_iter
