"""L1 Pallas kernel: blocked ELL gather-reduce.

This is the compute hot-spot of the paper, re-thought for a TPU-style
machine (see DESIGN.md §Hardware-Adaptation):

- the paper's CUDA *thread-per-vertex* kernel (low in-degree vertices)
  becomes ``ell_block_sum(contrib, ell_idx[V, W])``: a tile of ``BLOCK_ROWS``
  vertices is processed per grid step, each row's W neighbor slots gathered
  and reduced across vector lanes — no divergence, one store per vertex.
- the paper's CUDA *block-per-vertex* kernel (high in-degree vertices) is the
  same kernel over the hub chunk matrix ``hub_edges[NC, C]``: each row is one
  VMEM-sized chunk of a single hub's neighbor list ("strided block
  reduction"), reduced to a partial sum; the per-hub combine is a tiny
  segment-sum done in L2.

``interpret=True`` is mandatory: the artifacts must run on the CPU PJRT
backend (real-TPU lowering emits Mosaic custom-calls the CPU plugin cannot
execute). The BlockSpec structure below is what a real TPU deployment would
tile into VMEM; DESIGN.md §Perf estimates its VMEM footprint.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: rows of the ELL/chunk matrix processed per grid step. With W == 16 and
#: f64 ranks, one tile is BLOCK_ROWS×W×4 B of indices + BLOCK_ROWS×W×8 B of
#: gathered contributions + the resident contrib slice — comfortably inside
#: a 16 MiB VMEM budget at 256 rows.
BLOCK_ROWS = 256


def _reduce_kernel(contrib_ref, idx_ref, o_ref, *, op):
    """One grid step: gather a [rows, width] tile of contributions, reduce
    across the width (lane) axis, store one value per row."""
    contrib = contrib_ref[...]  # full contribution vector (HBM->VMEM slice)
    idx = idx_ref[...]  # [rows, width] neighbor ids for this tile
    vals = contrib[idx.reshape(-1)].reshape(idx.shape)
    if op == "sum":
        o_ref[...] = jnp.sum(vals, axis=1)
    elif op == "max":
        o_ref[...] = jnp.max(vals, axis=1)
    else:  # pragma: no cover
        raise ValueError(op)


def _ell_block_reduce(contrib: jax.Array, idx: jax.Array, op: str) -> jax.Array:
    n, w = idx.shape
    rows = min(BLOCK_ROWS, n)
    assert n % rows == 0, f"ELL rows {n} not divisible by tile {rows}"
    return pl.pallas_call(
        functools.partial(_reduce_kernel, op=op),
        grid=(n // rows,),
        in_specs=[
            pl.BlockSpec(contrib.shape, lambda i: (0,)),  # whole contrib vec
            pl.BlockSpec((rows, w), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), contrib.dtype),
        interpret=True,
    )(contrib, idx)


def ell_block_sum(contrib: jax.Array, idx: jax.Array) -> jax.Array:
    """Per-row sum of ``contrib[idx]``. ``contrib: f64[V]``, ``idx: i32[N, W]``
    (sentinel-padded; the sentinel's contribution must be 0) -> ``f64[N]``."""
    return _ell_block_reduce(contrib, idx, "sum")


def ell_block_max(flags: jax.Array, idx: jax.Array) -> jax.Array:
    """Per-row max of ``flags[idx]`` — the pull (gather) form of frontier
    expansion: vertex v becomes affected iff any in-neighbor has its
    "mark my out-neighbors" flag set. Atomics-free, one write per vertex."""
    return _ell_block_reduce(flags, idx, "max")
