"""End-to-end PageRank in Python, driving the device-format model functions
exactly the way the Rust coordinator drives the compiled artifacts — the
correctness signal for the whole device pipeline before Rust is involved."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import formats, model
from compile.kernels import ref
from conftest import pack, pad_ranks, random_graph, random_hub_graph

TAU = 1e-10
MAX_IT = 500


def run_static_device(adj, tier, dev, r0=None):
    n = len(adj)
    r = pad_ranks(np.full(n, 1.0 / n) if r0 is None else r0, tier)
    step = model.make_step_plain(tier)
    for it in range(MAX_IT):
        r_new, linf = step(
            r, dev["outdeg_inv"], dev["valid"], dev["inv_n"],
            dev["ell_idx"], dev["hub_edges"], dev["hub_seg"],
        )
        r = r_new
        if float(linf[0]) <= TAU:
            return np.asarray(r)[:n], it + 1
    return np.asarray(r)[:n], MAX_IT


def run_df_device(adj, tier, dev, r0, deletions, insertions, *, prune):
    n = len(adj)
    dv_s, dn_s = ref.initial_affected_ref(n, deletions, insertions)
    dv = formats.pad_vec(dv_s, tier.v)
    dn = formats.pad_vec(dn_s, tier.v)
    expand = model.make_expand_pull(tier)
    step = model.make_step_df(tier, prune=prune)
    graph = (dev["ell_idx"], dev["hub_edges"], dev["hub_seg"])
    dv = expand(dv, dn, *graph)
    r = pad_ranks(r0, tier)
    for it in range(MAX_IT):
        r_new, dv, dn, linf = step(
            r, dev["outdeg_inv"], dev["valid"], dev["inv_n"], *graph, dv
        )
        r = r_new
        if float(linf[0]) <= TAU:
            return np.asarray(r)[:n], it + 1
        dv = expand(dv, dn, *graph)
    return np.asarray(r)[:n], MAX_IT


def _apply_update(adj, rng, n_ins, n_del):
    """Random batch update (insert/delete), keeping self-loops intact."""
    n = len(adj)
    adj2 = [list(vs) for vs in adj]
    deletions, insertions = [], []
    edges = [(u, v) for u, vs in enumerate(adj2) for v in vs if u != v]
    rng.shuffle(edges)
    for u, v in edges[:n_del]:
        adj2[u].remove(v)
        deletions.append((u, v))
    for _ in range(n_ins):
        u, v = int(rng.integers(0, n)), int(rng.integers(0, n))
        if u != v and v not in adj2[u]:
            adj2[u].append(v)
            insertions.append((u, v))
    return adj2, deletions, insertions


@settings(max_examples=8, deadline=None)
@given(n=st.integers(5, 100), seed=st.integers(0, 2**32 - 1))
def test_static_device_matches_oracle(n, seed):
    rng = np.random.default_rng(seed)
    adj = random_hub_graph(rng, n) if n > 40 else random_graph(rng, n)
    tier, dev = pack(adj)
    got, _ = run_static_device(adj, tier, dev)
    want, _ = ref.naive_pagerank(adj)
    np.testing.assert_allclose(got, want, atol=1e-9)
    assert got.sum() == pytest.approx(1.0, abs=1e-6)


@settings(max_examples=6, deadline=None)
@given(
    n=st.integers(10, 80),
    seed=st.integers(0, 2**32 - 1),
    prune=st.booleans(),
)
def test_df_device_converges_to_static_ranks(n, seed, prune):
    """DF/DF-P on the updated graph ends close to a from-scratch static run
    (the paper's acceptability criterion, §5.3.1)."""
    rng = np.random.default_rng(seed)
    adj = random_graph(rng, n, avg_deg=5.0)
    tier, dev = pack(adj)
    r_prev, _ = run_static_device(adj, tier, dev)

    adj2, deletions, insertions = _apply_update(adj, rng, n_ins=3, n_del=2)
    tier2, dev2 = pack(adj2)
    got, iters = run_df_device(
        adj2, tier2, dev2, r_prev, deletions, insertions, prune=prune
    )
    want, _ = ref.naive_pagerank(adj2)
    # Frontier tolerances admit small per-vertex error (tau_f = 1e-6).
    err_l1 = np.abs(got - want).sum()
    assert err_l1 < 1e-3
    # ... and it matches the pure-python DF reference exactly.
    ref_r, ref_iters = ref.dynamic_frontier_pagerank(
        adj2, r_prev, deletions, insertions, prune=prune
    )
    np.testing.assert_allclose(got, ref_r, rtol=1e-9, atol=1e-12)
    assert iters == ref_iters


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 2**32 - 1))
def test_df_iterations_bounded_by_cold_start(seed):
    """Warm-start DF needs no more iterations than a cold static run for a
    tiny batch (DF-P's pruning can stretch the L-inf tail on adversarial
    seeds, so the strict "fewer" claim is asserted for plain DF and a 2x
    envelope for DF-P)."""
    rng = np.random.default_rng(seed)
    n = 400
    adj = random_graph(rng, n, avg_deg=6.0)
    tier, dev = pack(adj)
    r_prev, static_iters = run_static_device(adj, tier, dev)
    adj2, deletions, insertions = _apply_update(adj, rng, n_ins=2, n_del=1)
    tier2, dev2 = pack(adj2)
    _, df_iters = run_df_device(
        adj2, tier2, dev2, r_prev, deletions, insertions, prune=False
    )
    assert df_iters <= static_iters
    _, dfp_iters = run_df_device(
        adj2, tier2, dev2, r_prev, deletions, insertions, prune=True
    )
    assert dfp_iters <= 2 * static_iters


def test_nd_warm_start_converges_faster():
    rng = np.random.default_rng(1)
    n = 300
    adj = random_graph(rng, n, avg_deg=5.0)
    tier, dev = pack(adj)
    r_prev, cold_iters = run_static_device(adj, tier, dev)
    adj2, _, _ = _apply_update(adj, rng, n_ins=3, n_del=2)
    tier2, dev2 = pack(adj2)
    _, warm_iters = run_static_device(adj2, tier2, dev2, r0=r_prev)
    _, cold2_iters = run_static_device(adj2, tier2, dev2)
    assert warm_iters < cold2_iters
