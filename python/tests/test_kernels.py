"""L1 kernel correctness: Pallas kernels vs the numpy oracle, and the
XLA-fused implementations vs the Pallas ones (they must agree exactly —
the production artifacts bake the fused forms)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import fused, ref

DTYPES = [np.float64, np.float32]


def _mk(rng, n_src, rows, width, dtype):
    contrib = rng.random(n_src).astype(dtype)
    idx = rng.integers(0, n_src, (rows, width)).astype(np.int32)
    return contrib, idx


@settings(max_examples=30, deadline=None)
@given(
    n_src=st.integers(8, 300),
    rows=st.sampled_from([1, 2, 4, 8, 16, 64, 256, 512]),
    width=st.integers(1, 24),
    dtype=st.sampled_from(DTYPES),
    seed=st.integers(0, 2**32 - 1),
)
def test_ell_block_sum_matches_ref(n_src, rows, width, dtype, seed):
    rng = np.random.default_rng(seed)
    contrib, idx = _mk(rng, n_src, rows, width, dtype)
    got = np.asarray(kernels.ell_block_sum(contrib, idx))
    want = ref.ell_sum_ref(contrib, idx)
    np.testing.assert_allclose(got, want, rtol=1e-6 if dtype == np.float32 else 1e-12)


@settings(max_examples=30, deadline=None)
@given(
    n_src=st.integers(8, 300),
    rows=st.sampled_from([1, 4, 16, 256]),
    width=st.integers(1, 24),
    seed=st.integers(0, 2**32 - 1),
)
def test_ell_block_max_matches_ref(n_src, rows, width, seed):
    rng = np.random.default_rng(seed)
    flags = (rng.random(n_src) < 0.3).astype(np.float64)
    idx = rng.integers(0, n_src, (rows, width)).astype(np.int32)
    got = np.asarray(kernels.ell_block_max(flags, idx))
    np.testing.assert_array_equal(got, ref.ell_max_ref(flags, idx))


@settings(max_examples=30, deadline=None)
@given(
    n=st.sampled_from([1, 2, 64, 1024, 4096]),
    dtype=st.sampled_from(DTYPES),
    seed=st.integers(0, 2**32 - 1),
)
def test_linf_delta_matches_ref(n, dtype, seed):
    rng = np.random.default_rng(seed)
    a = rng.random(n).astype(dtype)
    b = rng.random(n).astype(dtype)
    got = np.asarray(kernels.linf_delta(a, b))
    assert got.shape == (1,)
    np.testing.assert_allclose(got[0], ref.linf_ref(a, b), rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    n_src=st.integers(8, 200),
    rows=st.sampled_from([4, 64, 256]),
    width=st.integers(1, 20),
    seed=st.integers(0, 2**32 - 1),
)
def test_fused_equals_pallas(n_src, rows, width, seed):
    """The production (fused) kernels and the Pallas kernels are the same
    function; sums may differ by reduction order only (~1 ulp)."""
    rng = np.random.default_rng(seed)
    contrib, idx = _mk(rng, n_src, rows, width, np.float64)
    np.testing.assert_allclose(
        np.asarray(fused.ell_block_sum(contrib, idx)),
        np.asarray(kernels.ell_block_sum(contrib, idx)),
        rtol=1e-14,
    )
    flags = (contrib > 0.5).astype(np.float64)
    np.testing.assert_array_equal(
        np.asarray(fused.ell_block_max(flags, idx)),
        np.asarray(kernels.ell_block_max(flags, idx)),
    )


@settings(max_examples=20, deadline=None)
@given(
    e=st.sampled_from([16, 256, 512]),
    n_seg=st.integers(2, 64),
    seed=st.integers(0, 2**32 - 1),
)
def test_onehot_segment_sum_matches_ref(e, n_seg, seed):
    rng = np.random.default_rng(seed)
    n_src = 128
    contrib = rng.random(n_src)
    src = rng.integers(0, n_src, e).astype(np.int32)
    seg = rng.integers(0, n_seg, e).astype(np.int32)
    got = np.asarray(kernels.onehot_segment_sum(contrib, src, seg, n_seg))
    want = ref.segment_sum_ref(contrib[src], seg, n_seg)
    np.testing.assert_allclose(got, want, rtol=1e-12)


def test_sentinel_contribution_is_zero():
    """Padding convention: gathering the sentinel slot must add exactly 0."""
    contrib = np.array([0.5, 0.25, 0.0])  # sentinel = last slot
    idx = np.array([[0, 2, 2, 2], [1, 0, 2, 2]], dtype=np.int32)
    got = np.asarray(kernels.ell_block_sum(contrib, idx))
    np.testing.assert_array_equal(got, [0.5, 0.75])


@pytest.mark.parametrize("rows,width", [(256, 16), (1024, 16)])
def test_tier_shaped_ell(rows, width):
    """Exactly the shapes the artifacts use (t10 ELL / hub chunks)."""
    rng = np.random.default_rng(7)
    contrib, idx = _mk(rng, 1024, rows, width, np.float64)
    got = np.asarray(kernels.ell_block_sum(contrib, idx))
    np.testing.assert_allclose(got, ref.ell_sum_ref(contrib, idx), rtol=1e-12)
