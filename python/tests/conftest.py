import jax

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402

from compile import formats  # noqa: E402


def random_graph(rng: np.random.Generator, n: int, avg_deg: float = 4.0):
    """Random digraph with self-loops on every vertex (no dead ends)."""
    adj: list[list[int]] = [[v] for v in range(n)]
    m = int(avg_deg * n)
    if m:
        src = rng.integers(0, n, m)
        dst = rng.integers(0, n, m)
        seen = {(v, v) for v in range(n)}
        for u, v in zip(src.tolist(), dst.tolist()):
            if (u, v) not in seen:
                seen.add((u, v))
                adj[u].append(v)
    return adj


def random_hub_graph(rng: np.random.Generator, n: int):
    """Graph guaranteed to exercise the hub (block-per-vertex) path: vertex 0
    has in-degree > DEGREE_THRESHOLD."""
    adj = random_graph(rng, n)
    hub_in = rng.choice(n, size=min(n, formats.DEGREE_THRESHOLD * 2 + 3), replace=False)
    for u in hub_in.tolist():
        if 0 not in adj[u]:
            adj[u].append(0)
    return adj


def pack(adj, tier=None):
    tier = tier or formats.TIERS[0]
    dev = formats.build_device_graph(adj, tier)
    return tier, dev


def pad_ranks(r, tier):
    return formats.pad_vec(np.asarray(r, dtype=np.float64), tier.v)
