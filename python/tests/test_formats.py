"""Device-format builder invariants — the layout contract with Rust."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import formats
from conftest import random_graph, random_hub_graph


def _decode_in_neighbors(dev, tier, n):
    """Reconstruct the in-adjacency from the packed ELL + hub chunks."""
    sentinel = tier.v - 1
    adj_in = [[] for _ in range(n)]
    for v in range(n):
        for u in dev["ell_idx"][v]:
            if u != sentinel:
                adj_in[v].append(int(u))
    for row in range(tier.nc):
        v = int(dev["hub_seg"][row])
        if v == sentinel:
            continue
        for u in dev["hub_edges"][row]:
            if u != sentinel:
                adj_in[v].append(int(u))
    return adj_in


@settings(max_examples=25, deadline=None)
@given(n=st.integers(2, 200), seed=st.integers(0, 2**32 - 1))
def test_pack_roundtrip(n, seed):
    """ELL + hub chunks + flat edges all encode exactly the input graph."""
    rng = np.random.default_rng(seed)
    adj = random_hub_graph(rng, n) if n > 40 else random_graph(rng, n)
    tier = formats.TIERS[0]
    dev = formats.build_device_graph(adj, tier)

    tadj = formats.transpose_adj(adj)
    got_in = _decode_in_neighbors(dev, tier, n)
    for v in range(n):
        assert sorted(got_in[v]) == sorted(tadj[v])

    # flat edge list matches the out-adjacency
    sentinel = tier.v - 1
    edges = [
        (int(s), int(d))
        for s, d in zip(dev["te_src"], dev["te_dst"])
        if s != sentinel or d != sentinel
    ]
    want = [(u, v) for u, vs in enumerate(adj) for v in vs]
    assert sorted(edges) == sorted(want)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(2, 150), seed=st.integers(0, 2**32 - 1))
def test_pack_scalars(n, seed):
    rng = np.random.default_rng(seed)
    adj = random_graph(rng, n)
    tier = formats.TIERS[0]
    dev = formats.build_device_graph(adj, tier)
    assert dev["inv_n"][0] == pytest.approx(1.0 / n)
    np.testing.assert_array_equal(dev["valid"][:n], 1.0)
    np.testing.assert_array_equal(dev["valid"][n:], 0.0)
    for v in range(n):
        assert dev["outdeg_inv"][v] == pytest.approx(1.0 / len(adj[v]))
    np.testing.assert_array_equal(dev["outdeg_inv"][n:], 0.0)
    # sentinel slot must never contribute
    assert dev["outdeg_inv"][tier.v - 1] == 0.0


def test_low_degree_rows_in_ell_hub_rows_empty():
    """A pure ring (in-degree 2 incl. self-loop) uses no hub chunks."""
    n = 64
    adj = [[v, (v + 1) % n] for v in range(n)]
    tier = formats.TIERS[0]
    dev = formats.build_device_graph(adj, tier)
    sentinel = tier.v - 1
    assert (dev["hub_seg"] == sentinel).all()
    assert (dev["hub_edges"] == sentinel).all()


def test_hub_vertex_routed_to_chunks():
    """in-degree > W vertices get all-sentinel ELL rows + chunk rows."""
    n = 50
    hub = 0
    adj = [[v] for v in range(n)]
    for u in range(1, n):
        adj[u].append(hub)  # hub in-degree = n-1 + self = 50 > 16
    tier = formats.TIERS[0]
    dev = formats.build_device_graph(adj, tier)
    sentinel = tier.v - 1
    assert (dev["ell_idx"][hub] == sentinel).all()
    rows = np.nonzero(dev["hub_seg"] == hub)[0]
    assert len(rows) == int(np.ceil(n / tier.c))
    packed = [int(u) for r in rows for u in dev["hub_edges"][r] if u != sentinel]
    assert sorted(packed) == sorted(range(n))


def test_last_chunk_row_reserved():
    """Row NC-1 is the worklist sentinel target and must stay unused."""
    rng = np.random.default_rng(3)
    adj = random_hub_graph(rng, 120)
    tier = formats.TIERS[0]
    dev = formats.build_device_graph(adj, tier)
    sentinel = tier.v - 1
    assert dev["hub_seg"][tier.nc - 1] == sentinel
    assert (dev["hub_edges"][tier.nc - 1] == sentinel).all()


def test_dead_end_rejected():
    adj = [[0, 1], []]  # vertex 1 is a dead end
    with pytest.raises(AssertionError, match="dead end"):
        formats.build_device_graph(adj, formats.TIERS[0])


def test_capacity_rejected():
    tier = formats.TIERS[0]
    n = tier.v  # n > V-1
    adj = [[v] for v in range(n)]
    with pytest.raises(AssertionError):
        formats.build_device_graph(adj, tier)


def test_tier_selection():
    assert formats.smallest_fitting_tier(100, 100).name == "t10"
    assert formats.smallest_fitting_tier(2000, 100).name == "t12"
    assert formats.smallest_fitting_tier(5000, 100).name == "t13"
    assert formats.smallest_fitting_tier(100, 1 << 16).name == "t12"
    assert formats.smallest_fitting_tier(100, (1 << 16) + 1).name == "t13"
    assert formats.smallest_fitting_tier(1 << 20, 10) is None


def test_out_side_mirrors_in_side():
    """out_ell/out_hub encode the out-adjacency with the same conventions."""
    rng = np.random.default_rng(11)
    adj = random_hub_graph(rng, 90)
    tier = formats.TIERS[0]
    dev = formats.build_device_graph(adj, tier)
    sentinel = tier.v - 1
    got = [[] for _ in range(len(adj))]
    for v in range(len(adj)):
        got[v].extend(int(u) for u in dev["out_ell_idx"][v] if u != sentinel)
    for row in range(tier.nc):
        u = int(dev["out_hub_seg"][row])
        if u != sentinel:
            got[u].extend(int(x) for x in dev["out_hub_edges"][row] if x != sentinel)
    for v in range(len(adj)):
        assert sorted(got[v]) == sorted(adj[v])
