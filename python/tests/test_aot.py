"""AOT lowering smoke tests: every artifact lowers to parseable HLO text and
the manifest describes it accurately."""

import json
import os
import subprocess
import sys

import jax
import pytest

from compile import aot, formats, model


@pytest.fixture(scope="module")
def t10_entries(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    tier = formats.tier_by_name("t10")
    entries = aot.lower_tier(tier, str(out), impl="fused")
    return out, tier, entries


def test_all_artifacts_lower(t10_entries):
    out, tier, entries = t10_entries
    names = {e["name"] for e in entries}
    assert names == set(model.artifact_specs(tier).keys())
    for e in entries:
        path = os.path.join(str(out), e["file"])
        text = open(path).read()
        assert "ENTRY" in text and "HloModule" in text
        # return_tuple=True: root computation returns a tuple
        assert "tuple(" in text or "ROOT" in text


def test_manifest_shapes_match_specs(t10_entries):
    _, tier, entries = t10_entries
    specs = model.artifact_specs(tier)
    for e in entries:
        _, inputs, outputs = specs[e["name"]]
        assert [i["name"] for i in e["inputs"]] == [n for n, _, _ in inputs]
        for i, (_, shape, dtype) in zip(e["inputs"], inputs):
            assert i["shape"] == list(shape)
        assert e["outputs"] == outputs


def test_hlo_parameter_count_matches_manifest(t10_entries):
    out, _, entries = t10_entries
    for e in entries:
        text = open(os.path.join(str(out), e["file"])).read()
        entry = text[text.index("ENTRY") :]
        body = entry[: entry.index("\n\n")] if "\n\n" in entry else entry
        n_params = body.count("parameter(")
        assert n_params == len(e["inputs"]), e["name"]


def test_aot_cli_writes_manifest(tmp_path):
    env = dict(os.environ, PYTHONPATH=os.path.dirname(os.path.dirname(__file__)))
    subprocess.run(
        [
            sys.executable,
            "-m",
            "compile.aot",
            "--out-dir",
            str(tmp_path),
            "--tiers",
            "t10",
        ],
        check=True,
        cwd=os.path.dirname(os.path.dirname(__file__)),
    )
    manifest = json.load(open(tmp_path / "manifest.json"))
    assert manifest["kernel_impl"] == "fused"
    assert manifest["constants"]["alpha"] == 0.85
    assert len(manifest["tiers"]) == 1
    t = manifest["tiers"][0]
    assert (t["v"], t["ecap"]) == (1 << 10, 1 << 14)
    assert t["wl_cap"] == t["v"] // 16
    for e in manifest["artifacts"]:
        assert (tmp_path / e["file"]).exists()
