"""L2 model vs the numpy step oracle: every step/expand artifact variant."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import formats, model
from compile.kernels import ref
from conftest import pack, pad_ranks, random_graph, random_hub_graph


def _random_state(rng, n, tier):
    r_small = rng.random(n)
    r_small /= r_small.sum()
    r = pad_ranks(r_small, tier)
    aff = formats.pad_vec((rng.random(n) < 0.6).astype(np.float64), tier.v)
    return r_small, r, aff


def _graph_args(dev):
    return dev["ell_idx"], dev["hub_edges"], dev["hub_seg"]


@settings(max_examples=15, deadline=None)
@given(n=st.integers(3, 150), seed=st.integers(0, 2**32 - 1))
def test_step_plain(n, seed):
    rng = np.random.default_rng(seed)
    adj = random_hub_graph(rng, n) if n > 40 else random_graph(rng, n)
    tier, dev = pack(adj)
    r_small, r, _ = _random_state(rng, n, tier)
    step = model.make_step_plain(tier)
    r_new, linf = step(
        r, dev["outdeg_inv"], dev["valid"], dev["inv_n"], *_graph_args(dev)
    )
    want, _, _, linf_want = ref.step_ref(r_small, adj, mode="plain")
    np.testing.assert_allclose(np.asarray(r_new)[:n], want, rtol=1e-12)
    np.testing.assert_array_equal(np.asarray(r_new)[n:], 0.0)
    assert np.isclose(float(linf[0]), linf_want, rtol=1e-9)


@settings(max_examples=12, deadline=None)
@given(
    n=st.integers(3, 120),
    seed=st.integers(0, 2**32 - 1),
    mode=st.sampled_from(["dt", "df", "dfp"]),
)
def test_step_masked_variants(n, seed, mode):
    rng = np.random.default_rng(seed)
    adj = random_hub_graph(rng, n) if n > 40 else random_graph(rng, n)
    tier, dev = pack(adj)
    r_small, r, aff = _random_state(rng, n, tier)
    aff_small = np.asarray(aff)[:n]

    want, aff_want, dn_want, linf_want = ref.step_ref(
        r_small, adj, mode=mode, aff=aff_small
    )

    if mode == "dt":
        step = model.make_step_dt(tier)
        r_new, linf = step(
            r, dev["outdeg_inv"], dev["valid"], dev["inv_n"],
            *_graph_args(dev), aff,
        )
    else:
        step = model.make_step_df(tier, prune=(mode == "dfp"))
        r_new, aff_out, delta_n, linf = step(
            r, dev["outdeg_inv"], dev["valid"], dev["inv_n"],
            *_graph_args(dev), aff,
        )
        np.testing.assert_array_equal(np.asarray(aff_out)[:n], aff_want)
        np.testing.assert_array_equal(np.asarray(delta_n)[:n], dn_want)

    np.testing.assert_allclose(np.asarray(r_new)[:n], want, rtol=1e-12)
    assert np.isclose(float(linf[0]), linf_want, rtol=1e-9)


@settings(max_examples=12, deadline=None)
@given(
    n=st.integers(3, 120),
    seed=st.integers(0, 2**32 - 1),
    prune=st.booleans(),
)
def test_step_nopart_equals_partitioned(n, seed, prune):
    """Figure-1 ablation: both work distributions compute the same step."""
    rng = np.random.default_rng(seed)
    adj = random_hub_graph(rng, n) if n > 40 else random_graph(rng, n)
    tier, dev = pack(adj)
    _, r, aff = _random_state(rng, n, tier)

    part = model.make_step_df(tier, prune=prune)
    flat = model.make_step_df(tier, prune=prune, partitioned=False)
    out_p = part(
        r, dev["outdeg_inv"], dev["valid"], dev["inv_n"], *_graph_args(dev), aff
    )
    out_f = flat(
        r, dev["outdeg_inv"], dev["valid"], dev["inv_n"],
        dev["te_src"], dev["te_dst"], aff,
    )
    for a, b in zip(out_p, out_f):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-12)


def _worklists(dev, aff, tier):
    """Host-side worklist construction (mirrors rust/src/runtime/tier.rs)."""
    sentinel = tier.v - 1
    ids = np.nonzero(np.asarray(aff) > 0)[0]
    wl = np.full((tier.wl_cap,), sentinel, dtype=np.int32)
    wl[: len(ids)] = ids
    hub_seg = np.asarray(dev["hub_seg"])
    aff_np = np.asarray(aff)
    rows = np.nonzero((hub_seg != sentinel) & (aff_np[hub_seg] > 0))[0]
    wlc = np.full((tier.wl_chunk_cap,), tier.nc - 1, dtype=np.int32)
    wlc[: len(rows)] = rows
    return wl, wlc


@settings(max_examples=12, deadline=None)
@given(
    n=st.integers(3, 60),
    seed=st.integers(0, 2**32 - 1),
    prune=st.booleans(),
)
def test_step_worklist_equals_full(n, seed, prune):
    """The worklist-compacted step computes exactly the full-shape step."""
    rng = np.random.default_rng(seed)
    adj = random_hub_graph(rng, n) if n > 40 else random_graph(rng, n)
    tier, dev = pack(adj)
    _, r, aff = _random_state(rng, n, tier)
    wl, wlc = _worklists(dev, aff, tier)

    full = model.make_step_df(tier, prune=prune)
    wl_step = model.make_step_df_wl(tier, prune=prune)
    base_args = (
        r, dev["outdeg_inv"], dev["valid"], dev["inv_n"], *_graph_args(dev), aff
    )
    out_full = full(*base_args)
    out_wl = wl_step(*base_args, wl, wlc)
    for a, b in zip(out_full, out_wl):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-12)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(3, 120), seed=st.integers(0, 2**32 - 1))
def test_expand_variants_agree_with_ref(n, seed):
    rng = np.random.default_rng(seed)
    adj = random_hub_graph(rng, n) if n > 40 else random_graph(rng, n)
    tier, dev = pack(adj)
    dv_small = (rng.random(n) < 0.2).astype(np.float64)
    dn_small = (rng.random(n) < 0.3).astype(np.float64)
    dv = formats.pad_vec(dv_small, tier.v)
    dn = formats.pad_vec(dn_small, tier.v)
    want = ref.expand_ref(dv_small, dn_small, adj)

    pull = model.make_expand_pull(tier)
    got = np.asarray(pull(dv, dn, *_graph_args(dev)))[:n]
    np.testing.assert_array_equal(got, want)

    scat = model.make_expand_scatter(tier)
    got = np.asarray(
        scat(dv, dn, dev["out_ell_idx"], dev["out_hub_edges"], dev["out_hub_seg"])
    )[:n]
    np.testing.assert_array_equal(got, want)

    flat = model.make_expand_flat(tier)
    got = np.asarray(flat(dv, dn, dev["te_src"], dev["te_dst"]))[:n]
    np.testing.assert_array_equal(got, want)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(3, 60), seed=st.integers(0, 2**32 - 1))
def test_expand_scatter_worklist(n, seed):
    rng = np.random.default_rng(seed)
    adj = random_hub_graph(rng, n) if n > 40 else random_graph(rng, n)
    tier, dev = pack(adj)
    dv_small = (rng.random(n) < 0.2).astype(np.float64)
    dn_small = (rng.random(n) < 0.3).astype(np.float64)
    dv = formats.pad_vec(dv_small, tier.v)
    dn = formats.pad_vec(dn_small, tier.v)
    want = ref.expand_ref(dv_small, dn_small, adj)

    # worklist over dn (out-side): affected-neighbor vertices + their chunks
    sentinel = tier.v - 1
    ids = np.nonzero(np.asarray(dn) > 0)[0]
    wl = np.full((tier.wl_cap,), sentinel, dtype=np.int32)
    wl[: len(ids)] = ids
    seg = np.asarray(dev["out_hub_seg"])
    rows = np.nonzero((seg != sentinel) & (np.asarray(dn)[seg] > 0))[0]
    wlc = np.full((tier.wl_chunk_cap,), tier.nc - 1, dtype=np.int32)
    wlc[: len(rows)] = rows

    swl = model.make_expand_scatter_wl(tier)
    got = np.asarray(
        swl(dv, dn, dev["out_ell_idx"], dev["out_hub_edges"],
            dev["out_hub_seg"], wl, wlc)
    )[:n]
    np.testing.assert_array_equal(got, want)


def test_pallas_impl_step_matches_fused():
    """Baking impl='pallas' into the step gives the same numbers."""
    rng = np.random.default_rng(0)
    adj = random_hub_graph(rng, 80)
    tier, dev = pack(adj)
    _, r, aff = _random_state(rng, 80, tier)
    args = (r, dev["outdeg_inv"], dev["valid"], dev["inv_n"], *_graph_args(dev), aff)
    out_f = model.make_step_df(tier, prune=True, impl="fused")(*args)
    out_p = model.make_step_df(tier, prune=True, impl="pallas")(*args)
    for a, b in zip(out_f, out_p):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-12)


def test_packed_wrappers_match_unpacked():
    """The packed (single-output) artifact wrappers compute exactly the
    unpacked functions, with the documented state layout."""
    rng = np.random.default_rng(5)
    n = 90
    adj = random_hub_graph(rng, n)
    tier, dev = pack(adj)
    v = tier.v
    r_small, r, aff = _random_state(rng, n, tier)
    dn0 = np.zeros(v)

    # step_dfp packed
    full = model.make_step_df(tier, prune=True)
    packed = model.make_step_df_packed(tier, prune=True)
    state = np.concatenate([np.asarray(r), np.asarray(aff), dn0, [0.0]])
    out = np.asarray(packed(
        state, dev["outdeg_inv"], dev["valid"], dev["inv_n"], *_graph_args(dev)
    ))
    r2, aff2, dn2, linf = full(
        r, dev["outdeg_inv"], dev["valid"], dev["inv_n"], *_graph_args(dev), aff
    )
    np.testing.assert_allclose(out[:v], np.asarray(r2), rtol=1e-15)
    np.testing.assert_array_equal(out[v:2*v], np.asarray(aff2))
    np.testing.assert_array_equal(out[2*v:3*v], np.asarray(dn2))
    assert out[3*v] == float(np.asarray(linf)[0])

    # expand_pull packed: aff segment updated, r/dn/linf pass through
    exp_full = model.make_expand_pull(tier)
    exp_packed = model.make_expand_pull_packed(tier)
    out2 = np.asarray(exp_packed(out, *_graph_args(dev)))
    want_aff = np.asarray(exp_full(out[v:2*v], out[2*v:3*v], *_graph_args(dev)))
    np.testing.assert_array_equal(out2[v:2*v], want_aff)
    np.testing.assert_array_equal(out2[:v], out[:v])
    np.testing.assert_array_equal(out2[2*v:], out[2*v:])

    # peeks
    peek_linf = model.make_peek_last(tier, 3*v+1)
    assert np.asarray(peek_linf(out)) == [out[3*v]]
    peek_ad = model.make_peek_aff_dn(tier)
    np.testing.assert_array_equal(np.asarray(peek_ad(out)), out[v:3*v])

    # step_plain packed (state1)
    plain_full = model.make_step_plain(tier)
    plain_packed = model.make_step_plain_packed(tier)
    st1 = np.concatenate([np.asarray(r), [0.0]])
    o1 = np.asarray(plain_packed(
        st1, dev["outdeg_inv"], dev["valid"], dev["inv_n"], *_graph_args(dev)
    ))
    rp, lp = plain_full(
        r, dev["outdeg_inv"], dev["valid"], dev["inv_n"], *_graph_args(dev)
    )
    np.testing.assert_allclose(o1[:v], np.asarray(rp), rtol=1e-15)
    assert o1[v] == float(np.asarray(lp)[0])
