# Make the build-time `compile` package importable when pytest runs from the
# repository root (the documented `pytest python/tests/` invocation).
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
