//! Bench: cost of the robustness layer on the serving path.
//!
//! Measures the three per-update overheads the fault-hardened coordinator
//! adds — batch validation, the rank-health watchdog check, and checkpoint
//! capture / JSON roundtrip — so the "safety is cheap relative to an engine
//! run" claim stays checkable as the layer evolves.

use std::time::Instant;

use pagerank_dynamic::batch::{self, validate, BatchUpdate};
use pagerank_dynamic::coordinator::{Checkpoint, DynamicGraphService, HealthConfig};
use pagerank_dynamic::coordinator::health::check_ranks;
use pagerank_dynamic::engines::native;
use pagerank_dynamic::generators::er;
use pagerank_dynamic::harness::fmt_dur;
use pagerank_dynamic::PagerankConfig;

fn main() {
    let cfg = PagerankConfig::default();
    let n = 100_000;
    let mut g = er::generate(n, 8.0, 42);
    g.ensure_self_loops();
    println!(
        "graph: {} vertices, {} edges\n",
        g.num_vertices(),
        g.num_edges()
    );

    // --- batch validation throughput (clean and adversarial batches)
    for (label, batch) in [
        ("validate clean 10k", batch::random_batch(&g, 10_000, 0.8, 1)),
        ("validate adversarial 10k", {
            let mut b = BatchUpdate::default();
            for i in 0..5_000u32 {
                b.insertions.push((n as u32 + i, i)); // out of range
                b.deletions.push((i % n as u32, i % n as u32)); // self-loop
            }
            b
        }),
    ] {
        let t0 = Instant::now();
        let iters = 20;
        let mut quarantined = 0;
        for _ in 0..iters {
            quarantined = validate(&g, &batch).quarantined();
        }
        let per = t0.elapsed() / iters;
        println!(
            "{label:<26} {:>10} /batch  ({} quarantined, {:.1} Medits/s)",
            fmt_dur(per),
            quarantined,
            batch.len() as f64 / per.as_secs_f64() / 1e6
        );
    }

    // --- watchdog check throughput
    let gc = g.to_csr();
    let gt = gc.transpose();
    let res = native::static_pagerank(&gc, &gt, &cfg, None);
    let t0 = Instant::now();
    let iters = 50;
    for _ in 0..iters {
        assert!(check_ranks(&res.ranks, n, res.iterations, &cfg, &HealthConfig::default())
            .is_empty());
    }
    let per = t0.elapsed() / iters;
    println!(
        "{:<26} {:>10} /check  ({:.1} Mranks/s)",
        "watchdog check_ranks",
        fmt_dur(per),
        n as f64 / per.as_secs_f64() / 1e6
    );
    println!(
        "{:<26} {:>10} /run    (engine static run, for scale)",
        "static_pagerank",
        fmt_dur(res.elapsed)
    );

    // --- checkpoint capture and JSON roundtrip
    let mut s = DynamicGraphService::new(g, None, cfg);
    s.apply_update(BatchUpdate::default()).unwrap();
    let t0 = Instant::now();
    let cp = s.checkpoint();
    println!("{:<26} {:>10}", "checkpoint capture", fmt_dur(t0.elapsed()));
    let t0 = Instant::now();
    let doc = cp.to_json();
    println!(
        "{:<26} {:>10}  ({:.1} MB)",
        "checkpoint to_json",
        fmt_dur(t0.elapsed()),
        doc.len() as f64 / 1e6
    );
    let t0 = Instant::now();
    let back = Checkpoint::from_json(&doc).unwrap();
    println!("{:<26} {:>10}", "checkpoint from_json", fmt_dur(t0.elapsed()));
    assert_eq!(back.edges.len(), cp.edges.len());
}
