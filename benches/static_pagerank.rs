//! Bench: Static PageRank end-to-end — device engine vs native CPU vs the
//! Hornet-like / Gunrock-like baselines (paper Table 1 / Figure 2).
//!
//! Plain-harness bench (offline build: no criterion): median of repeated
//! runs with warmup, printed as an aligned table.



use pagerank_dynamic::engines::baselines::{gunrock_like, hornet_like};
use pagerank_dynamic::engines::native;
use pagerank_dynamic::generators::families;
use pagerank_dynamic::harness::fmt_dur;
use pagerank_dynamic::runtime::{ArtifactStore, DeviceGraph};
use pagerank_dynamic::PagerankConfig;
use pagerank_dynamic::engines::device::DeviceEngine;

const REPEATS: usize = 3;

fn median(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}

fn bench<F: FnMut() -> std::time::Duration>(mut f: F) -> std::time::Duration {
    let _ = f(); // warmup
    let samples: Vec<f64> = (0..REPEATS).map(|_| f().as_secs_f64()).collect();
    std::time::Duration::from_secs_f64(median(samples))
}

fn main() {
    let cfg = PagerankConfig::default();
    let store = ArtifactStore::open_default().expect("make artifacts");
    let eng = DeviceEngine::new(&store);

    println!(
        "{:<18} {:>9} {:>9} {:>9} {:>9}  {:>8} {:>8}",
        "graph", "hornet", "gunrock", "ours-CPU", "ours-GPU", "vs hor", "vs gun"
    );
    for name in ["it-2004", "sk-2005", "com-Orkut", "asia_osm", "kmer_A2a"] {
        let d = families::dataset(name).unwrap();
        let g = d.build().to_csr();
        let gt = g.transpose();
        let tier = store.tier_for(g.num_vertices(), g.num_edges()).unwrap();
        let dg = DeviceGraph::pack(&g, &gt, &tier).unwrap();

        let t_h = bench(|| hornet_like(&g, &cfg).elapsed);
        let t_g = bench(|| gunrock_like(&g, &cfg).elapsed);
        let t_c = bench(|| native::static_pagerank(&g, &gt, &cfg, None).elapsed);
        let t_d = bench(|| eng.static_pagerank(&dg, &cfg, None).unwrap().elapsed);

        println!(
            "{:<18} {:>9} {:>9} {:>9} {:>9}  {:>7.1}x {:>7.1}x",
            name,
            fmt_dur(t_h),
            fmt_dur(t_g),
            fmt_dur(t_c),
            fmt_dur(t_d),
            t_h.as_secs_f64() / t_d.as_secs_f64(),
            t_g.as_secs_f64() / t_d.as_secs_f64(),
        );
    }
    println!("\n(paper: ours-GPU 31x vs Hornet, 5.9x vs Gunrock, 24x vs ours-CPU on A100)");
}
