//! Bench: Static PageRank end-to-end.
//!
//! Part 1 (always runs): native engine thread-scaling sweep — threads
//! 1/2/4/max, persistent work-stealing pool vs legacy per-region scoped
//! spawn, on a large and a small skewed RMAT web graph (the small one
//! isolates spawn overhead, the skew exercises stealing) — printed and
//! written as machine-readable `BENCH_native_scaling.json`.
//!
//! Part 2: device engine vs native CPU vs the Hornet-like / Gunrock-like
//! baselines (paper Table 1 / Figure 2). The device column requires
//! compiled artifacts (`make artifacts`) and prints `-` without them.
//!
//! Plain-harness bench (offline build: no criterion): median of repeated
//! runs with warmup, printed as an aligned table.

use std::fmt::Write as _;

use pagerank_dynamic::engines::baselines::{gunrock_like, hornet_like};
use pagerank_dynamic::engines::device::DeviceEngine;
use pagerank_dynamic::engines::native;
use pagerank_dynamic::generators::{families, rmat};
use pagerank_dynamic::harness::fmt_dur;
use pagerank_dynamic::runtime::{ArtifactStore, DeviceGraph};
use pagerank_dynamic::util::par;
use pagerank_dynamic::PagerankConfig;

const REPEATS: usize = 3;

fn median(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}

fn bench<F: FnMut() -> std::time::Duration>(mut f: F) -> std::time::Duration {
    let _ = f(); // warmup
    let samples: Vec<f64> = (0..REPEATS).map(|_| f().as_secs_f64()).collect();
    std::time::Duration::from_secs_f64(median(samples))
}

/// Thread counts to sweep: 1, 2, 4 and the full machine.
fn sweep_threads() -> Vec<usize> {
    let mut sweep = vec![1usize, 2, 4, par::available()];
    sweep.sort_unstable();
    sweep.dedup();
    sweep
}

fn native_scaling_sweep(cfg: &PagerankConfig) {
    // Two regimes: the large graph measures steady-state scaling (skewed
    // hubs exercise the stealing deques); the small graph runs many short
    // parallel regions, where the persistent pool's amortized spawns are
    // the whole difference.
    let graphs = [("rmat-web-large", 16u32, 16.0f64), ("rmat-web-small", 12, 8.0)];
    let mut rows = String::new();
    for (family, scale, avg_deg) in graphs {
        let b = rmat::generate(scale, avg_deg, rmat::RmatParams::WEB, 42);
        let g = b.to_csr();
        let gt = g.transpose();
        println!(
            "native static PageRank thread scaling ({family}, n={}, m={}, {} cores):",
            g.num_vertices(),
            g.num_edges(),
            par::available()
        );

        let mut t1 = f64::NAN;
        for t in sweep_threads() {
            let mut iterations = 0usize;
            let mut timed = |persistent: bool| {
                let c = cfg.with_threads(t).with_pool_persistent(persistent);
                bench(|| {
                    let r = native::static_pagerank(&g, &gt, &c, None);
                    iterations = r.iterations;
                    r.elapsed
                })
                .as_secs_f64()
            };
            let pool = timed(true);
            let spawn = timed(false);
            if t == 1 {
                t1 = pool;
            }
            println!(
                "  threads={:<3} pool {:>10}  spawn {:>10}  ({} iters, \
                 speedup {:.2}x, pool vs spawn {:.2}x)",
                t,
                fmt_dur(std::time::Duration::from_secs_f64(pool)),
                fmt_dur(std::time::Duration::from_secs_f64(spawn)),
                iterations,
                t1 / pool,
                spawn / pool
            );
            if !rows.is_empty() {
                rows.push_str(",\n");
            }
            let _ = write!(
                rows,
                "    {{\"graph\": \"{family}\", \"n\": {}, \"m\": {}, \
                 \"threads\": {t}, \"seconds_pool\": {pool:.6}, \
                 \"seconds_spawn\": {spawn:.6}, \"iterations\": {iterations}, \
                 \"speedup_vs_1\": {:.4}, \"pool_vs_spawn\": {:.4}}}",
                g.num_vertices(),
                g.num_edges(),
                t1 / pool,
                spawn / pool
            );
        }
    }
    let json = format!(
        "{{\n  \"bench\": \"native_static_scaling\",\n  \
         \"available_parallelism\": {},\n  \"results\": [\n{}\n  ]\n}}\n",
        par::available(),
        rows
    );
    if let Err(e) = std::fs::write("BENCH_native_scaling.json", &json) {
        eprintln!("could not write BENCH_native_scaling.json: {e}");
    } else {
        println!("  -> BENCH_native_scaling.json");
    }
}

fn main() {
    let cfg = PagerankConfig::default();

    native_scaling_sweep(&cfg);

    let store = match ArtifactStore::open_default() {
        Ok(s) => Some(s),
        Err(e) => {
            println!("\n(device column skipped: {e})");
            None
        }
    };
    let eng = store.as_ref().map(DeviceEngine::new);

    println!(
        "\n{:<18} {:>9} {:>9} {:>9} {:>9}  {:>8} {:>8}",
        "graph", "hornet", "gunrock", "ours-CPU", "ours-GPU", "vs hor", "vs gun"
    );
    for name in ["it-2004", "sk-2005", "com-Orkut", "asia_osm", "kmer_A2a"] {
        let d = families::dataset(name).unwrap();
        let g = d.build().to_csr();
        let gt = g.transpose();

        let t_h = bench(|| hornet_like(&g, &cfg).elapsed);
        let t_g = bench(|| gunrock_like(&g, &cfg).elapsed);
        let t_c = bench(|| native::static_pagerank(&g, &gt, &cfg, None).elapsed);
        let t_d = eng.as_ref().map(|eng| {
            let store = store.as_ref().unwrap();
            let tier = store.tier_for(g.num_vertices(), g.num_edges()).unwrap();
            let dg = DeviceGraph::pack(&g, &gt, &tier).unwrap();
            bench(|| eng.static_pagerank(&dg, &cfg, None).unwrap().elapsed)
        });

        match t_d {
            Some(t_d) => println!(
                "{:<18} {:>9} {:>9} {:>9} {:>9}  {:>7.1}x {:>7.1}x",
                name,
                fmt_dur(t_h),
                fmt_dur(t_g),
                fmt_dur(t_c),
                fmt_dur(t_d),
                t_h.as_secs_f64() / t_d.as_secs_f64(),
                t_g.as_secs_f64() / t_d.as_secs_f64(),
            ),
            None => println!(
                "{:<18} {:>9} {:>9} {:>9} {:>9}  {:>8} {:>8}",
                name,
                fmt_dur(t_h),
                fmt_dur(t_g),
                fmt_dur(t_c),
                "-",
                "-",
                "-",
            ),
        }
    }
    println!("\n(paper: ours-GPU 31x vs Hornet, 5.9x vs Gunrock, 24x vs ours-CPU on A100)");
}
