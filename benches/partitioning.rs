//! Bench: work-partitioning ablation for DF / DF-P (paper Figure 1) plus
//! worklist compaction on/off.

use pagerank_dynamic::batch::{self, random_batch};
use pagerank_dynamic::engines::device::{DeviceEngine, PartitionMode};
use pagerank_dynamic::engines::native;
use pagerank_dynamic::generators::families;
use pagerank_dynamic::harness::fmt_dur;
use pagerank_dynamic::runtime::{ArtifactStore, DeviceGraph};
use pagerank_dynamic::PagerankConfig;

fn main() {
    let cfg = PagerankConfig::default();
    let store = match ArtifactStore::open_default() {
        Ok(s) => s,
        Err(e) => {
            println!("bench skipped: {e} (run `make artifacts`)");
            return;
        }
    };
    let eng = DeviceEngine::new(&store);

    let d = families::dataset("it-2004").unwrap();
    let mut b = d.build();
    let g0 = b.to_csr();
    let gt0 = g0.transpose();
    let prev = native::static_pagerank(&g0, &gt0, &cfg, None).ranks;
    let upd = random_batch(&b, (g0.num_edges() / 20_000).max(4), 0.8, 7);
    batch::apply(&mut b, &upd);
    let g = b.to_csr();
    let gt = g.transpose();
    let tier = store.tier_for(g.num_vertices(), g.num_edges()).unwrap();
    let dg = DeviceGraph::pack(&g, &gt, &tier).unwrap();

    println!("it-2004 stand-in, batch {} edges\n", upd.len());
    println!("{:<28} {:>10} {:>10}", "configuration", "DF", "DF-P");
    for mode in [
        PartitionMode::DontPartition,
        PartitionMode::PartitionGPrime,
        PartitionMode::PartitionBoth,
        PartitionMode::PartitionBothPull,
    ] {
        for wl in [false, true] {
            if wl && mode == PartitionMode::DontPartition {
                continue; // worklist needs partitioned structures
            }
            let df = eng
                .dynamic_frontier(&dg, &g, &cfg, &prev, &upd, false, mode, wl)
                .unwrap();
            let dfp = eng
                .dynamic_frontier(&dg, &g, &cfg, &prev, &upd, true, mode, wl)
                .unwrap();
            println!(
                "{:<28} {:>10} {:>10}",
                format!("{}{}", mode.label(), if wl { " +wl" } else { "" }),
                fmt_dur(df.elapsed),
                fmt_dur(dfp.elapsed)
            );
        }
    }
    println!("\n(paper fig1: Partition G, G' fastest; nopart slowest)");
}
