//! Bench: per-update graph-maintenance latency, rebuild vs incremental.
//!
//! The quantity under test is `UpdateReport::maintenance` — everything the
//! coordinator does to the graph per update (validation + builder apply +
//! CSR upkeep + prev-snapshot bookkeeping), excluding the engine run. In
//! rebuild mode that is dominated by the O(N + E) `to_csr()` + `transpose()`
//! pair; in incremental mode by O(batch) patches on `graph::dyncsr`. Batch
//! sizes 10 → 10k on ≥100k-edge graphs, written as machine-readable
//! `BENCH_update_latency.json`; the headline claim is incremental ≥5x
//! cheaper than rebuild for batches ≤1k.

use std::fmt::Write as _;

use pagerank_dynamic::batch;
use pagerank_dynamic::coordinator::DynamicGraphService;
use pagerank_dynamic::generators::{er, rmat};
use pagerank_dynamic::graph::{CsrMode, GraphBuilder};
use pagerank_dynamic::harness::fmt_dur;
use pagerank_dynamic::PagerankConfig;

const BATCH_SIZES: [usize; 4] = [10, 100, 1_000, 10_000];
const REPS: usize = 3;

fn graphs() -> Vec<(&'static str, GraphBuilder)> {
    vec![
        ("er-100k", er::generate(100_000, 8.0, 42)),
        ("rmat-web-s16", rmat::generate(16, 8.0, rmat::RmatParams::WEB, 43)),
    ]
}

fn main() {
    let mut rows = String::new();
    let mut first = true;
    for (gname, b) in graphs() {
        let mut shadow = b.clone();
        shadow.ensure_self_loops();
        let mk = |mode: CsrMode| {
            DynamicGraphService::new(
                b.clone(),
                None,
                PagerankConfig::default().with_csr_mode(mode),
            )
        };
        let mut reb = mk(CsrMode::Rebuild);
        let mut inc = mk(CsrMode::Incremental);
        reb.ensure_ranks().unwrap();
        inc.ensure_ranks().unwrap();
        println!(
            "graph {gname}: {} vertices, {} edges",
            shadow.num_vertices(),
            shadow.num_edges()
        );
        println!(
            "{:>8} {:>14} {:>14} {:>9}",
            "batch", "rebuild", "incremental", "speedup"
        );

        let mut seed = 5_000u64;
        for size in BATCH_SIZES {
            // mean over REPS identical batch sequences; both services see
            // the same batches, so the graphs stay in lockstep throughout
            let (mut reb_ns, mut inc_ns) = (0u128, 0u128);
            for _ in 0..REPS {
                seed += 1;
                let upd = batch::random_batch(&shadow, size, 0.7, seed);
                batch::apply(&mut shadow, &upd);
                let rr = reb.apply_update(upd.clone()).unwrap();
                let ri = inc.apply_update(upd).unwrap();
                assert_eq!(rr.num_edges, ri.num_edges, "modes diverged");
                reb_ns += rr.maintenance.as_nanos();
                inc_ns += ri.maintenance.as_nanos();
            }
            let reb_mean = reb_ns as f64 / REPS as f64;
            let inc_mean = inc_ns as f64 / REPS as f64;
            let speedup = reb_mean / inc_mean.max(1.0);
            println!(
                "{:>8} {:>14} {:>14} {:>8.1}x",
                size,
                fmt_dur(std::time::Duration::from_nanos(reb_mean as u64)),
                fmt_dur(std::time::Duration::from_nanos(inc_mean as u64)),
                speedup
            );
            if !first {
                rows.push_str(",\n");
            }
            first = false;
            let _ = write!(
                rows,
                "    {{\"graph\": \"{gname}\", \"n\": {}, \"m\": {}, \"batch\": {size}, \
                 \"reps\": {REPS}, \"rebuild_maintenance_ns\": {:.0}, \
                 \"incremental_maintenance_ns\": {:.0}, \"speedup\": {speedup:.2}}}",
                shadow.num_vertices(),
                shadow.num_edges(),
                reb_mean,
                inc_mean
            );
        }
        println!();
    }

    let json = format!(
        "{{\n  \"bench\": \"update_latency\",\n  \"metric\": \
         \"UpdateReport.maintenance (graph upkeep per update, engine time excluded)\",\n  \
         \"results\": [\n{rows}\n  ]\n}}\n"
    );
    if let Err(e) = std::fs::write("BENCH_update_latency.json", &json) {
        eprintln!("could not write BENCH_update_latency.json: {e}");
    } else {
        println!("  -> BENCH_update_latency.json");
    }
}
