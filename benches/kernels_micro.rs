//! Bench: kernel-level microbenchmarks.
//!
//! Part 1 (always runs): the native engine's constituent kernels — the
//! per-iteration pull step (contrib + degree-partitioned rank update), the
//! parallel frontier expansion, and the parallel graph builders (transpose,
//! edge-list CSR, Algorithm 4 partition) — swept over threads 1/2/4/max,
//! written as machine-readable `BENCH_native_kernels.json`.
//!
//! Part 2: per-launch latency of each device artifact (step variants,
//! expansions, peeks, the standalone Pallas kernels) across tiers — the
//! L1/L2 profile that drives the perf pass (EXPERIMENTS.md §Perf). Requires
//! compiled artifacts (`make artifacts`); skipped without them.

use std::fmt::Write as _;
use std::time::Instant;

use pagerank_dynamic::engines::native::{self, affected};
use pagerank_dynamic::generators::rmat;
use pagerank_dynamic::graph::partition::partition_by_degree_threads;
use pagerank_dynamic::graph::CsrGraph;
use pagerank_dynamic::harness::fmt_dur;
use pagerank_dynamic::runtime::artifacts::{lit_f64, lit_i32_2d, run};
use pagerank_dynamic::runtime::exec::{buf_f64, buf_i32, exec1, GraphBufs};
use pagerank_dynamic::runtime::ArtifactStore;
use pagerank_dynamic::util::par;
use pagerank_dynamic::util::simd::{self, SimdPolicy};
use pagerank_dynamic::PagerankConfig;

const REPEATS: usize = 7;

fn bench_ns<F: FnMut()>(mut f: F) -> f64 {
    f(); // warmup (compilation cached already)
    let mut best = f64::MAX;
    for _ in 0..REPEATS {
        let t = Instant::now();
        f();
        best = best.min(t.elapsed().as_secs_f64());
    }
    best
}

fn native_kernel_sweep() {
    let cfg = PagerankConfig::default();
    let b = rmat::generate(15, 10.0, rmat::RmatParams::WEB, 3);
    let g = b.to_csr();
    let gt = g.transpose();
    let edges: Vec<(u32, u32)> = g.edges().collect();
    let degrees = gt.degrees();
    let n = g.num_vertices();
    let m = g.num_edges();
    println!(
        "=== native kernels (RMAT web, n={n} m={m}, {} cores) ===",
        par::available()
    );

    let mut sweep = vec![1usize, 2, 4, par::available()];
    sweep.sort_unstable();
    sweep.dedup();

    let mut rows = String::new();
    let mut record = |kernel: &str, threads: usize, secs: f64| {
        println!(
            "  {:<22} threads={:<3} {:>10}  ({:.1} Medges/s)",
            kernel,
            threads,
            fmt_dur(std::time::Duration::from_secs_f64(secs)),
            m as f64 / secs / 1e6
        );
        if !rows.is_empty() {
            rows.push_str(",\n");
        }
        let _ = write!(
            rows,
            "    {{\"kernel\": \"{kernel}\", \"threads\": {threads}, \"seconds\": {secs:.9}}}"
        );
    };

    for &t in &sweep {
        // per-iteration pull step: contrib + degree-partitioned rank update
        // (full static run divided by its iteration count), on each SIMD
        // backend — ranks are bitwise identical, only wall-clock moves
        for (suffix, simd) in [("_scalar", SimdPolicy::Scalar), ("_simd", SimdPolicy::Vector)]
        {
            let c = cfg.with_threads(t).with_simd(simd);
            let mut iters = 1usize;
            let run_secs = bench_ns(|| {
                let r = native::static_pagerank(&g, &gt, &c, None);
                iters = r.iterations.max(1);
            });
            record(&format!("step_plain_iter{suffix}"), t, run_secs / iters as f64);
        }

        record("transpose", t, bench_ns(|| {
            std::hint::black_box(g.transpose_threads(t));
        }));
        record("from_edges", t, bench_ns(|| {
            std::hint::black_box(CsrGraph::from_edges_threads(n, &edges, t));
        }));
        record("partition_by_degree", t, bench_ns(|| {
            std::hint::black_box(partition_by_degree_threads(&degrees, 32, t));
        }));

        // frontier expansion with a ~10% frontier
        let mut dn = vec![0u8; n];
        for v in (0..n).step_by(10) {
            dn[v] = 1;
        }
        record("expand_affected", t, bench_ns(|| {
            let mut dv = vec![0u8; n];
            affected::expand_affected_threads(&mut dv, &dn, &g, t);
            std::hint::black_box(dv);
        }));
    }

    // util::simd kernel micros, per backend (single lane, full arrays):
    // the pull gather, the contribution pass, and the convergence norms —
    // the rows ci reads to confirm the vector path is no slower than scalar
    {
        let mut backends = vec![("scalar", simd::Backend::Portable)];
        if simd::detect() != simd::Backend::Portable {
            backends.push(("simd", simd::detect()));
        }
        let values: Vec<f64> = (0..n).map(|v| 1.0 / (v + 1) as f64).collect();
        let values2: Vec<f64> = (0..n).map(|v| 1.0 / (v + 2) as f64).collect();
        let mut out = vec![0.0f64; n];
        let targets = gt.targets();
        let offsets = g.offsets();
        for (bname, be) in backends {
            record(&format!("gather_sum_{bname}"), 1, bench_ns(|| {
                std::hint::black_box(simd::gather_sum(be, &values, targets));
            }));
            record(&format!("contrib_block_{bname}"), 1, bench_ns(|| {
                // packed CSR: row bounds are (offsets[..n], offsets[1..])
                std::hint::black_box(simd::contrib_block(
                    be,
                    &offsets[..n],
                    &offsets[1..],
                    &values,
                    0,
                    &mut out,
                ));
            }));
            record(&format!("l1_{bname}"), 1, bench_ns(|| {
                std::hint::black_box(simd::l1(be, &values, &values2));
            }));
            record(&format!("linf_{bname}"), 1, bench_ns(|| {
                std::hint::black_box(simd::linf(be, &values, &values2));
            }));
        }
    }

    let json = format!(
        "{{\n  \"bench\": \"native_kernels_scaling\",\n  \"graph\": \
         {{\"family\": \"rmat-web\", \"scale\": 15, \"n\": {n}, \"m\": {m}}},\n  \
         \"available_parallelism\": {},\n  \"results\": [\n{}\n  ]\n}}\n",
        par::available(),
        rows
    );
    if let Err(e) = std::fs::write("BENCH_native_kernels.json", &json) {
        eprintln!("could not write BENCH_native_kernels.json: {e}");
    } else {
        println!("  -> BENCH_native_kernels.json");
    }
}

fn device_micro() {
    let store = match ArtifactStore::open_default() {
        Ok(s) => s,
        Err(e) => {
            println!("\n(device micro section skipped: {e})");
            return;
        }
    };
    let cfg = PagerankConfig::default();

    for (tier_name, scale, deg) in [("t10", 9u32, 6.0), ("t13", 12, 8.0), ("t16", 15, 10.0)] {
        let tier = store.manifest().tier(tier_name).unwrap().clone();
        let b = rmat::generate(scale, deg, rmat::RmatParams::WEB, 3);
        let g = b.to_csr();
        let gt = g.transpose();
        let dg = pagerank_dynamic::runtime::DeviceGraph::pack(&g, &gt, &tier).unwrap();
        println!(
            "\n=== tier {tier_name}: V={} ECAP={} NC={} (graph n={} m={}) ===",
            tier.v,
            tier.ecap,
            tier.nc,
            g.num_vertices(),
            g.num_edges()
        );

        let bufs = GraphBufs::build(&store, &dg).unwrap();
        let ranks = native::static_pagerank(&g, &gt, &cfg, None).ranks;

        // packed states: [r | linf] and [r | aff | dn | linf]
        let mut s1 = dg.pad(&ranks);
        s1.push(0.0);
        let state1 = buf_f64(&store, &s1, &[tier.v + 1]).unwrap();
        let mut s3 = dg.pad(&ranks);
        s3.extend(vec![1.0; tier.v]); // aff = all
        s3.extend(vec![0.0; tier.v + 1]); // dn, linf
        let state3 = buf_f64(&store, &s3, &[3 * tier.v + 1]).unwrap();

        let row = |name: &str, t: f64| {
            println!(
                "  {:<24} {:>10}  ({:.1} Medges/s)",
                name,
                fmt_dur(std::time::Duration::from_secs_f64(t)),
                g.num_edges() as f64 / t / 1e6
            );
        };

        let exe = store.executable("step_plain", tier_name).unwrap();
        row("step_plain", bench_ns(|| {
            exec1(&exe, &[
                &state1, &bufs.odi, &bufs.valid, &bufs.inv_n,
                &bufs.ell, &bufs.hub_edges, &bufs.hub_seg,
            ])
            .unwrap();
        }));

        let exe = store.executable("step_dfp", tier_name).unwrap();
        row("step_dfp (all aff)", bench_ns(|| {
            exec1(&exe, &[
                &state3, &bufs.odi, &bufs.valid, &bufs.inv_n,
                &bufs.ell, &bufs.hub_edges, &bufs.hub_seg,
            ])
            .unwrap();
        }));

        let exe = store.executable("step_dfp_nopart", tier_name).unwrap();
        row("step_dfp_nopart", bench_ns(|| {
            exec1(&exe, &[
                &state3, &bufs.odi, &bufs.valid, &bufs.inv_n,
                &bufs.te_src, &bufs.te_dst,
            ])
            .unwrap();
        }));

        // worklist variant with a ~2% frontier
        let mut flags = vec![0.0; tier.v];
        for v in (0..dg.n).step_by(dg.n / 50 + 1) {
            flags[v] = 1.0;
        }
        if let Some((wl, wlc)) = dg.worklists(&flags, &dg.in_side) {
            let wl_b = buf_i32(&store, &wl, &[tier.wl_cap]).unwrap();
            let wlc_b = buf_i32(&store, &wlc, &[tier.wl_chunk_cap]).unwrap();
            let exe = store.executable("step_dfp_wl", tier_name).unwrap();
            row("step_dfp_wl (~2% aff)", bench_ns(|| {
                exec1(&exe, &[
                    &state3, &bufs.odi, &bufs.valid, &bufs.inv_n,
                    &bufs.ell, &bufs.hub_edges, &bufs.hub_seg, &wl_b, &wlc_b,
                ])
                .unwrap();
            }));
        }

        let exe = store.executable("expand_pull", tier_name).unwrap();
        row("expand_pull", bench_ns(|| {
            exec1(&exe, &[&state3, &bufs.ell, &bufs.hub_edges, &bufs.hub_seg]).unwrap();
        }));

        let exe = store.executable("expand_flat", tier_name).unwrap();
        row("expand_flat", bench_ns(|| {
            exec1(&exe, &[&state3, &bufs.te_src, &bufs.te_dst]).unwrap();
        }));

        let exe = store.executable("peek_linf3", tier_name).unwrap();
        row("peek_linf3 (8B read)", bench_ns(|| {
            exec1(&exe, &[&state3]).unwrap();
        }));

        // standalone Pallas kernels (interpret-mode cost — the production
        // steps bake the fused forms; see kernels/fused.py)
        let contrib = lit_f64(&dg.outdeg_inv);
        let ell_lit = lit_i32_2d(&dg.in_side.ell, tier.v, tier.w).unwrap();
        let exe = store.executable("kernel_ell_sum", tier_name).unwrap();
        row("pallas ell_sum", bench_ns(|| {
            run(&exe, &[&contrib, &ell_lit]).unwrap();
        }));
        let a_lit = lit_f64(&dg.outdeg_inv);
        let b_lit = lit_f64(&dg.valid);
        let exe = store.executable("kernel_linf", tier_name).unwrap();
        row("pallas linf", bench_ns(|| {
            run(&exe, &[&a_lit, &b_lit]).unwrap();
        }));
    }
}

fn main() {
    native_kernel_sweep();
    device_micro();
}
