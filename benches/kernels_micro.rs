//! Bench: kernel-level microbenchmarks — per-launch latency of each
//! artifact (step variants, expansions, peeks, the standalone Pallas
//! kernels) across tiers. This is the L1/L2 profile that drives the perf
//! pass (EXPERIMENTS.md §Perf).

use std::time::Instant;

use pagerank_dynamic::engines::native;
use pagerank_dynamic::generators::rmat;
use pagerank_dynamic::harness::fmt_dur;
use pagerank_dynamic::runtime::exec::{buf_f64, buf_i32, exec1, GraphBufs};
use pagerank_dynamic::runtime::artifacts::{lit_f64, lit_i32_2d, run};
use pagerank_dynamic::runtime::ArtifactStore;
use pagerank_dynamic::PagerankConfig;

const REPEATS: usize = 7;

fn bench_ns<F: FnMut()>(mut f: F) -> f64 {
    f(); // warmup (compilation cached already)
    let mut best = f64::MAX;
    for _ in 0..REPEATS {
        let t = Instant::now();
        f();
        best = best.min(t.elapsed().as_secs_f64());
    }
    best
}

fn main() {
    let store = ArtifactStore::open_default().expect("make artifacts");
    let cfg = PagerankConfig::default();

    for (tier_name, scale, deg) in [("t10", 9u32, 6.0), ("t13", 12, 8.0), ("t16", 15, 10.0)] {
        let tier = store.manifest().tier(tier_name).unwrap().clone();
        let b = rmat::generate(scale, deg, rmat::RmatParams::WEB, 3);
        let g = b.to_csr();
        let gt = g.transpose();
        let dg = pagerank_dynamic::runtime::DeviceGraph::pack(&g, &gt, &tier).unwrap();
        println!(
            "\n=== tier {tier_name}: V={} ECAP={} NC={} (graph n={} m={}) ===",
            tier.v,
            tier.ecap,
            tier.nc,
            g.num_vertices(),
            g.num_edges()
        );

        let bufs = GraphBufs::build(&store, &dg).unwrap();
        let ranks = native::static_pagerank(&g, &gt, &cfg, None).ranks;

        // packed states: [r | linf] and [r | aff | dn | linf]
        let mut s1 = dg.pad(&ranks);
        s1.push(0.0);
        let state1 = buf_f64(&store, &s1, &[tier.v + 1]).unwrap();
        let mut s3 = dg.pad(&ranks);
        s3.extend(vec![1.0; tier.v]); // aff = all
        s3.extend(vec![0.0; tier.v + 1]); // dn, linf
        let state3 = buf_f64(&store, &s3, &[3 * tier.v + 1]).unwrap();

        let row = |name: &str, t: f64| {
            println!(
                "  {:<24} {:>10}  ({:.1} Medges/s)",
                name,
                fmt_dur(std::time::Duration::from_secs_f64(t)),
                g.num_edges() as f64 / t / 1e6
            );
        };

        let exe = store.executable("step_plain", tier_name).unwrap();
        row("step_plain", bench_ns(|| {
            exec1(&exe, &[
                &state1, &bufs.odi, &bufs.valid, &bufs.inv_n,
                &bufs.ell, &bufs.hub_edges, &bufs.hub_seg,
            ])
            .unwrap();
        }));

        let exe = store.executable("step_dfp", tier_name).unwrap();
        row("step_dfp (all aff)", bench_ns(|| {
            exec1(&exe, &[
                &state3, &bufs.odi, &bufs.valid, &bufs.inv_n,
                &bufs.ell, &bufs.hub_edges, &bufs.hub_seg,
            ])
            .unwrap();
        }));

        let exe = store.executable("step_dfp_nopart", tier_name).unwrap();
        row("step_dfp_nopart", bench_ns(|| {
            exec1(&exe, &[
                &state3, &bufs.odi, &bufs.valid, &bufs.inv_n,
                &bufs.te_src, &bufs.te_dst,
            ])
            .unwrap();
        }));

        // worklist variant with a ~2% frontier
        let mut flags = vec![0.0; tier.v];
        for v in (0..dg.n).step_by(dg.n / 50 + 1) {
            flags[v] = 1.0;
        }
        if let Some((wl, wlc)) = dg.worklists(&flags, &dg.in_side) {
            let wl_b = buf_i32(&store, &wl, &[tier.wl_cap]).unwrap();
            let wlc_b = buf_i32(&store, &wlc, &[tier.wl_chunk_cap]).unwrap();
            let exe = store.executable("step_dfp_wl", tier_name).unwrap();
            row("step_dfp_wl (~2% aff)", bench_ns(|| {
                exec1(&exe, &[
                    &state3, &bufs.odi, &bufs.valid, &bufs.inv_n,
                    &bufs.ell, &bufs.hub_edges, &bufs.hub_seg, &wl_b, &wlc_b,
                ])
                .unwrap();
            }));
        }

        let exe = store.executable("expand_pull", tier_name).unwrap();
        row("expand_pull", bench_ns(|| {
            exec1(&exe, &[&state3, &bufs.ell, &bufs.hub_edges, &bufs.hub_seg]).unwrap();
        }));

        let exe = store.executable("expand_flat", tier_name).unwrap();
        row("expand_flat", bench_ns(|| {
            exec1(&exe, &[&state3, &bufs.te_src, &bufs.te_dst]).unwrap();
        }));

        let exe = store.executable("peek_linf3", tier_name).unwrap();
        row("peek_linf3 (8B read)", bench_ns(|| {
            exec1(&exe, &[&state3]).unwrap();
        }));

        // standalone Pallas kernels (interpret-mode cost — the production
        // steps bake the fused forms; see kernels/fused.py)
        let contrib = lit_f64(&dg.outdeg_inv);
        let ell_lit = lit_i32_2d(&dg.in_side.ell, tier.v, tier.w).unwrap();
        let exe = store.executable("kernel_ell_sum", tier_name).unwrap();
        row("pallas ell_sum", bench_ns(|| {
            run(&exe, &[&contrib, &ell_lit]).unwrap();
        }));
        let a_lit = lit_f64(&dg.outdeg_inv);
        let b_lit = lit_f64(&dg.valid);
        let exe = store.executable("kernel_linf", tier_name).unwrap();
        row("pallas linf", bench_ns(|| {
            run(&exe, &[&a_lit, &b_lit]).unwrap();
        }));
    }
}
