//! Bench: the five update approaches across batch sizes on the device
//! engine (paper Figures 3/4 in miniature).

use pagerank_dynamic::batch::{self, random_batch};
use pagerank_dynamic::engines::{native, Approach};
use pagerank_dynamic::generators::families;
use pagerank_dynamic::harness::experiments::{Runner, Substrate};
use pagerank_dynamic::harness::fmt_dur;
use pagerank_dynamic::runtime::ArtifactStore;
use pagerank_dynamic::PagerankConfig;

fn main() {
    let cfg = PagerankConfig::default();
    let store = match ArtifactStore::open_default() {
        Ok(s) => std::sync::Arc::new(s),
        Err(e) => {
            println!("bench skipped: {e} (run `make artifacts`)");
            return;
        }
    };
    let runner = Runner { store: Some(store), cfg };

    for name in ["com-LiveJournal", "asia_osm"] {
        let d = families::dataset(name).unwrap();
        let base = d.build();
        let g0 = base.to_csr();
        let gt0 = g0.transpose();
        let prev = native::static_pagerank(&g0, &gt0, &cfg, None).ranks;
        let m = g0.num_edges();
        println!("\n{name} (n={}, m={m})", g0.num_vertices());
        println!(
            "{:>10} {:>10} {:>10} {:>10} {:>10} {:>10}  {:>8}",
            "B/|E|", "Static", "ND", "DT", "DF", "DF-P", "DFP-spdp"
        );
        for frac in [1e-6f64, 1e-5, 1e-4, 1e-3] {
            let bsize = ((m as f64 * frac).round() as usize).max(1);
            let mut b = base.clone();
            let upd = random_batch(&b, bsize, 0.8, 1234);
            let old = b.to_csr();
            batch::apply(&mut b, &upd);
            let g = b.to_csr();
            let gt = g.transpose();

            let mut t = std::collections::HashMap::new();
            for a in Approach::ALL {
                let res = runner
                    .run(a, Substrate::Device, &g, &gt, &old, Some(&prev), &upd)
                    .unwrap();
                t.insert(a, res.elapsed);
            }
            println!(
                "{:>10.0e} {:>10} {:>10} {:>10} {:>10} {:>10}  {:>7.1}x",
                frac,
                fmt_dur(t[&Approach::Static]),
                fmt_dur(t[&Approach::NaiveDynamic]),
                fmt_dur(t[&Approach::DynamicTraversal]),
                fmt_dur(t[&Approach::DynamicFrontier]),
                fmt_dur(t[&Approach::DynamicFrontierPruning]),
                t[&Approach::Static].as_secs_f64()
                    / t[&Approach::DynamicFrontierPruning].as_secs_f64()
            );
        }
    }
    println!("\n(paper fig4: DF-P 3.1x over Static for small random batches)");
}
